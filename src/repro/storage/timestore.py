"""Online time-series store — TPU-native adaptation of the refined skiplist.

The paper's §7.2 structure is a two-level skiplist: level 1 sorted by key,
level 2 per-key linked lists sorted by timestamp, with lock-free CAS
inserts and batch TTL eviction.  Pointer-chasing has no TPU analogue, so we
keep the *invariant* (data pre-ranked by (key, ts) so online access is a
seek + contiguous scan) in a dense representation:

    keys : (capacity,) int32   sorted ascending; padding = INT32_MAX
    ts   : (capacity,) int32   sorted within each key run; padding = MAX
    cols : {name: (capacity,) float32/int32}
    count: ()        int32     live rows

All operations are pure jax (jit-able, static shapes):

  * ``insert``       O(capacity) vectorized shift (a write is a roll of the
                     suffix — fully parallel on a vector unit, unlike a CAS
                     chain, and single-writer per shard matches the paper's
                     replicator-lock serialization anyway),
  * ``range_bounds`` O(log capacity) via branchless binary search,
  * ``evict_before`` batch TTL deletion (§7.2): drop every row with
                     ts < horizon in one compaction pass,
  * a host-side ``binlog`` (insert sequence numbers) drives asynchronous
    pre-aggregation updates exactly like the paper's
    ``replicator->AppendEntry`` (§5.1 Aggregator Update).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INT_MAX = np.int32(2**31 - 1)

__all__ = ["StoreState", "OnlineStore", "ShardedOnlineStore",
           "StoreSnapshot", "insert", "insert_many",
           "insert_many_stacked", "range_bounds", "evict_before",
           "gather_window", "gather_key_unit", "next_pow2"]


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1): batch-size padding that keeps
    jit recompiles logarithmic in batch size."""
    return 1 << max(0, (n - 1).bit_length())

# StoreState is a plain pytree: dict with fixed structure.
StoreState = Dict


def make_state(capacity: int, col_specs: Dict[str, jnp.dtype]) -> StoreState:
    return {
        "keys": jnp.full((capacity,), INT_MAX, jnp.int32),
        "ts": jnp.full((capacity,), INT_MAX, jnp.int32),
        "cols": {name: jnp.zeros((capacity,), dtype)
                 for name, dtype in col_specs.items()},
        "count": jnp.zeros((), jnp.int32),
    }


def _bsearch(keys: jnp.ndarray, tss: jnp.ndarray, key, ts,
             strict: bool) -> jnp.ndarray:
    """Branchless binary search over the (key, ts)-sorted store:
    first index i with (keys[i], ts[i]) > (key, ts)   [strict=True]
    or >= (key, ts)                                    [strict=False].
    O(log capacity) scalar gathers — the dense-array analogue of the
    skiplist seek (§7.2): pre-ranked data makes access logarithmic,
    never a scan."""
    n = keys.shape[0]
    steps = max(1, (n - 1).bit_length() + 1)
    lo = jnp.int32(0)
    hi = jnp.int32(n)

    def body(_, carry):
        lo_, hi_ = carry
        mid = (lo_ + hi_) // 2
        m = jnp.clip(mid, 0, n - 1)
        k_m = keys[m]
        t_m = tss[m]
        if strict:
            gt = (k_m > key) | ((k_m == key) & (t_m > ts))
        else:
            gt = (k_m > key) | ((k_m == key) & (t_m >= ts))
        go_left = gt & (lo_ < hi_)
        hi_ = jnp.where(go_left, mid, hi_)
        lo_ = jnp.where(go_left | (lo_ >= hi_), lo_, mid + 1)
        return lo_, hi_

    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo.astype(jnp.int32)


def insert_pos(state: StoreState, key, ts) -> jnp.ndarray:
    """First index i with (keys[i], ts[i]) > (key, ts): insert *after*
    peers, preserving arrival order among equal timestamps (this is what
    makes online replay bitwise-match the offline stable sort).  Padding
    rows (INT_MAX keys) always compare "after"."""
    pos = _bsearch(state["keys"], state["ts"], key, ts, strict=True)
    return jnp.minimum(pos, state["count"])


@jax.jit
def insert(state: StoreState, key, ts, values: Dict[str, jnp.ndarray]
           ) -> StoreState:
    """Sorted insert of one row (vectorized suffix shift)."""
    pos = insert_pos(state, key, ts)
    idx = jnp.arange(state["keys"].shape[0], dtype=jnp.int32)

    def shifted(arr, new_val):
        prev = jnp.roll(arr, 1)
        out = jnp.where(idx > pos, prev, arr)
        return jnp.where(idx == pos, jnp.asarray(new_val, arr.dtype), out)

    new_cols = {}
    for name, arr in state["cols"].items():
        new_cols[name] = shifted(arr, values.get(name, 0))
    return {
        "keys": shifted(state["keys"], key),
        "ts": shifted(state["ts"], ts),
        "cols": new_cols,
        "count": state["count"] + 1,
    }


@jax.jit
def insert_many(state: StoreState, keys, ts, values: Dict[str, jnp.ndarray],
                n_new) -> StoreState:
    """Sorted insert of a padded batch of rows with ONE merge.

    ``keys``/``ts`` are (M,) int32 with padding rows carrying INT_MAX in
    both; ``n_new`` is the number of real rows.  Cost is one
    O((capacity+M) log) lexsort instead of M O(capacity) suffix shifts —
    the bulk-ingest analogue of the skiplist's batch build.

    Ordering matches M sequential ``insert`` calls: new rows land *after*
    existing peers with equal (key, ts) (existing rows carry smaller
    arrival ranks), and arrival order among the new rows themselves is
    preserved (rank = capacity + j).  Rows sorted beyond ``capacity`` are
    dropped — the host wrapper guarantees they are padding only.
    """
    cap = state["keys"].shape[0]
    m = keys.shape[0]
    rank = jnp.concatenate([jnp.arange(cap, dtype=jnp.int32),
                            cap + jnp.arange(m, dtype=jnp.int32)])
    all_keys = jnp.concatenate([state["keys"], jnp.asarray(keys, jnp.int32)])
    all_ts = jnp.concatenate([state["ts"], jnp.asarray(ts, jnp.int32)])
    perm = jnp.lexsort((rank, all_ts, all_keys))[:cap]

    new_cols = {}
    for name, arr in state["cols"].items():
        v = jnp.asarray(values.get(name, jnp.zeros((m,), arr.dtype)),
                        arr.dtype)
        new_cols[name] = jnp.take(jnp.concatenate([arr, v]), perm, axis=0)
    return {
        "keys": jnp.take(all_keys, perm),
        "ts": jnp.take(all_ts, perm),
        "cols": new_cols,
        "count": state["count"] + jnp.asarray(n_new, jnp.int32),
    }


@jax.jit
def insert_many_stacked(states: StoreState, keys, ts,
                        values: Dict[str, jnp.ndarray], n_new) -> StoreState:
    """``insert_many`` vmapped over a leading shard dim.

    ``states`` leaves carry shape (n_shards, capacity, ...); ``keys``/``ts``
    are (n_shards, M) blocks whose non-owned slots hold INT_MAX padding and
    ``n_new`` is the per-shard real-row count.  Every op is elementwise
    along the shard dim, so under a sharded-in/sharded-out jit the merge
    stays local to each shard's device (no cross-shard traffic).
    """
    return jax.vmap(insert_many)(states, keys, ts, values, n_new)


@jax.jit
def evict_before_stacked(states: StoreState, horizon_ts) -> StoreState:
    return jax.vmap(evict_before, in_axes=(0, None))(states, horizon_ts)


def range_bounds(state: StoreState, key, t0, t1) -> Tuple[jnp.ndarray,
                                                          jnp.ndarray]:
    """[lo, hi) of rows with keys==key and ts in [t0, t1] (peers at t1
    included — matches the position-based offline semantics when the
    querying row is about to be inserted after its peers).  Two binary
    searches: O(log capacity), independent of table size."""
    keys, tss = state["keys"], state["ts"]
    n = state["count"]
    lo = jnp.minimum(_bsearch(keys, tss, key, t0, strict=False), n)
    hi = jnp.minimum(_bsearch(keys, tss, key, t1, strict=True), n)
    lo = jnp.minimum(lo, hi)
    return lo, hi


@jax.jit
def evict_before(state: StoreState, horizon_ts) -> StoreState:
    """Batch TTL eviction (§7.2): remove all rows with ts < horizon.

    Dense-array equivalent of the skiplist's contiguous-head deletion:
    one stable compaction (keep-mask prefix sum + scatter).
    """
    keys, tss = state["keys"], state["ts"]
    cap = keys.shape[0]
    idx = jnp.arange(cap, dtype=jnp.int32)
    live = idx < state["count"]
    keep = live & (tss >= horizon_ts)
    dest = jnp.cumsum(keep.astype(jnp.int32)) - 1
    # out-of-bounds destinations are dropped by the scatter
    scatter_to = jnp.where(keep, dest, cap)

    def compact(arr, fill):
        out = jnp.full_like(arr, fill)
        return out.at[scatter_to].set(arr, mode="drop")

    new_cols = {k: compact(v, 0) for k, v in state["cols"].items()}
    return {
        "keys": compact(keys, INT_MAX),
        "ts": compact(tss, INT_MAX),
        "cols": new_cols,
        "count": jnp.sum(keep.astype(jnp.int32)),
    }


def gather_key_unit(state: StoreState, key, ts, max_rows: int,
                    col_names: List[str]
                    ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray,
                               jnp.ndarray]:
    """Unit-layout adapter: one key's WHOLE history up to ``ts``.

    Gathers the newest ``max_rows`` rows of ``key`` with timestamps <=
    ``ts`` (peers at ``ts`` included — the querying request inserts
    after its peers) into the fixed (cols, ts, valid) buffers the unit
    fold core consumes.  The gather anchors at the key segment's FIRST
    row, not the window start: that is what makes the online request
    fold replay the offline unit fold bitwise (same rows, same unit
    positions, same prefix-scan anchor).  When a key's history exceeds
    ``max_rows`` the oldest context rows are dropped — window semantics
    survive as long as the window rows fit, but float equality vs the
    offline fold degrades to reduction-order tolerance.
    """
    lo, hi = range_bounds(state, key, jnp.int32(-2**31), ts)
    return gather_window(state, lo, hi, max_rows, col_names)


def gather_window(state: StoreState, lo: jnp.ndarray, hi: jnp.ndarray,
                  max_rows: int, col_names: List[str]
                  ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray,
                             jnp.ndarray]:
    """Gather the newest ``max_rows`` rows of [lo, hi) into fixed buffers.

    Returns (cols, ts, valid).  Rows are in time order; if the range holds
    more than ``max_rows`` rows only the most recent are kept (the same
    truncation MAXSIZE applies to windows).
    """
    start = jnp.maximum(lo, hi - max_rows)
    base = jnp.arange(max_rows, dtype=jnp.int32)
    idx = start + base
    valid = idx < hi
    safe = jnp.clip(idx, 0, state["keys"].shape[0] - 1)
    cols = {c: jnp.take(state["cols"][c], safe, axis=0)
            for c in col_names}
    ts = jnp.take(state["ts"], safe, axis=0)
    return cols, ts, valid


class StoreSnapshot:
    """Immutable point-in-time read view of a store — the snapshot half
    of the serving loop's double buffer (``serve.loop.ServeLoop``).

    Cutting a snapshot is O(#tables): every ``StoreState`` leaf is an
    immutable jnp array and every store mutation *replaces* whole table
    entries (``self.tables[t] = insert(...)``) instead of writing in
    place, so a shallow copy of the ``tables`` dict IS a consistent
    frozen view — no array is ever copied.  The sharded routing state
    (``assignment``) is frozen with it so a concurrent ``rebalance()``
    cannot desynchronize a snapshot's routing from its resident rows.

    The view quacks like the store for the READ surface the online
    drivers touch (``tables``, ``capacity``, and for sharded stores
    ``n_shards``/``mesh``/``axis``/``owner_of_keys``), so
    ``CompiledScript.online_batch`` / ``online_sharded_batch`` run
    against it unchanged — including their two-level jitted-fn cache,
    which keys on the view's (stable) identity.

    ``refresh()`` re-cuts from the live store *in place*: a single
    attribute rebind per field, so readers in the serving loop see
    either the old frozen view or the new one, never a mix — the atomic
    swap that lets ``ingest_many`` + compaction + replication shipping
    proceed on the live store without stalling (or dirtying) in-flight
    requests.
    """

    def __init__(self, store):
        self._store = store
        self.capacity = store.capacity
        self.col_specs = store.col_specs
        self.sharded = isinstance(store, ShardedOnlineStore)
        if self.sharded:
            self.n_shards = store.n_shards
            self.mesh = store.mesh
            self.axis = store.axis
            self.n_route_slots = store.n_route_slots
        self.version = -1
        self.refresh()

    def refresh(self) -> int:
        """Atomically re-cut the view from the live store; returns the
        new snapshot version."""
        store = self._store
        self.tables = dict(store.tables)
        if self.sharded:
            self.assignment = store.assignment.copy()
        self.version += 1
        return self.version

    # ------------------------------------------------ read-only surface
    def route_slots(self, keys) -> np.ndarray:
        from ..core.hll import splitmix64

        k = np.atleast_1d(np.asarray(keys)).astype(np.uint64)
        return (splitmix64(k) % np.uint64(self.n_route_slots)).astype(
            np.int64)

    def owner_of_keys(self, keys) -> np.ndarray:
        """Key -> owning shard under the FROZEN assignment."""
        return self.assignment[self.route_slots(keys)].astype(np.int64)

    def n_rows_per_shard(self, table: str) -> np.ndarray:
        return np.asarray(self.tables[table]["count"])

    def n_rows(self, table: str) -> int:
        return int(np.sum(np.asarray(self.tables[table]["count"])))


class _BinlogMixin:
    """Bounded binlog shared by both stores.

    Offsets are STABLE across truncation: ``self.binlog`` holds entries
    [``_binlog_base``, ``_binlog_offset``) and ``read_binlog`` addresses
    by absolute offset.  ``truncate_binlog`` drops entries below a
    consumer low-watermark (the pre-aggregation consumed offset — see
    ``serve.engine.FeatureEngine``) so a long-lived store's log stays
    bounded instead of growing with total ingest.
    """

    def read_binlog(self, from_offset: int):
        if from_offset < self._binlog_base:
            raise ValueError(
                f"binlog offset {from_offset} was truncated (log now "
                f"starts at {self._binlog_base}); consumers must keep "
                f"their read offset at or above the truncation "
                f"low-watermark")
        return (self.binlog[from_offset - self._binlog_base:],
                self._binlog_offset)

    def truncate_binlog(self, below_offset: int) -> int:
        """Drop binlog entries below ``below_offset`` (clamped to the
        written end).  Returns the number of entries dropped.  Offsets
        of the surviving entries are unchanged."""
        upto = min(int(below_offset), self._binlog_offset)
        drop = upto - self._binlog_base
        if drop <= 0:
            return 0
        del self.binlog[:drop]
        self._binlog_base = upto
        return drop


class OnlineStore(_BinlogMixin):
    """Host-facing wrapper: one StoreState per table + a binlog.

    The binlog (monotone offsets, host side) decouples pre-aggregation
    updates from the insert path, mirroring §5.1's asynchronous
    ``update_aggr`` closures: consumers (PreAggregator) read the log tail
    and fold new rows into their buckets.  Consumed entries are dropped
    by ``truncate_binlog`` (offsets stay stable).
    """

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.tables: Dict[str, StoreState] = {}
        self.col_specs: Dict[str, Dict[str, jnp.dtype]] = {}
        self.binlog: List[Tuple[str, int, int, Dict[str, float]]] = []
        self._binlog_offset = 0
        self._binlog_base = 0

    def create_table(self, name: str, col_specs: Dict[str, jnp.dtype]):
        self.tables[name] = make_state(self.capacity, col_specs)
        self.col_specs[name] = dict(col_specs)

    def bulk_load(self, table: str, keys, ts, cols: Dict[str, "np.ndarray"]
                  ) -> int:
        """LOAD DATA path: sort once by (key, ts, arrival) and overwrite
        the table state (paper Figure 3's offline->online sync)."""
        keys = np.asarray(keys, np.int32)
        ts = np.asarray(ts, np.int32)
        n = keys.shape[0]
        if n > self.capacity:
            raise ValueError(f"bulk load of {n} rows exceeds capacity "
                             f"{self.capacity}")
        order = np.lexsort((np.arange(n), ts, keys))
        st = make_state(self.capacity, self.col_specs[table])
        st["keys"] = st["keys"].at[:n].set(jnp.asarray(keys[order]))
        st["ts"] = st["ts"].at[:n].set(jnp.asarray(ts[order]))
        for name in st["cols"]:
            arr = np.asarray(cols[name])[order]
            st["cols"][name] = st["cols"][name].at[:n].set(
                jnp.asarray(arr, st["cols"][name].dtype))
        st["count"] = jnp.asarray(n, jnp.int32)
        self.tables[table] = st
        ko = keys[order].tolist()
        tso = ts[order].tolist()
        # entries carry the column values: the binlog must be a FULL
        # record of every row so a replica/recovery replay of the log
        # rebuilds the state bitwise (storage.replication)
        co = {c: np.asarray(cols[c])[order].tolist() for c in cols}
        self.binlog.extend(
            (table, ko[i], tso[i], {c: float(co[c][i]) for c in co})
            for i in range(n))
        self._binlog_offset += n
        return n

    def put(self, table: str, key: int, ts: int,
            values: Dict[str, float]) -> int:
        """Insert + append to binlog; returns the binlog offset."""
        st = self.tables[table]
        self.tables[table] = insert(st, jnp.int32(key), jnp.int32(ts),
                                    {k: jnp.asarray(v) for k, v in
                                     values.items()})
        off = self._binlog_offset
        self.binlog.append((table, int(key), int(ts), dict(values)))
        self._binlog_offset += 1
        return off

    def put_many(self, table: str, keys, ts,
                 cols: Dict[str, "np.ndarray"]) -> int:
        """Bulk insert of N rows with one sort-merge (vs N O(capacity)
        shifts for sequential ``put``); returns the first binlog offset.

        Equivalent to ``put``-ing the rows in order: rows are appended to
        the binlog in arrival order and land after existing (key, ts)
        peers in the store.  Batches are padded to the next power of two
        so jit recompiles stay logarithmic in batch size.
        """
        keys = np.asarray(keys, np.int32)
        ts = np.asarray(ts, np.int32)
        n = keys.shape[0]
        if n == 0:
            return self._binlog_offset
        if self.n_rows(table) + n > self.capacity:
            raise ValueError(f"bulk put of {n} rows overflows capacity "
                             f"{self.capacity}")
        m = next_pow2(n)
        k_pad = np.full((m,), INT_MAX, np.int32)
        t_pad = np.full((m,), INT_MAX, np.int32)
        k_pad[:n] = keys
        t_pad[:n] = ts
        specs = self.col_specs[table]
        vals = {}
        for name, dtype in specs.items():
            v = np.zeros((m,), dtype)
            if name in cols:
                v[:n] = np.asarray(cols[name], dtype)
            vals[name] = jnp.asarray(v)
        self.tables[table] = insert_many(
            self.tables[table], jnp.asarray(k_pad), jnp.asarray(t_pad),
            vals, n)
        off = self._binlog_offset
        kl, tl = keys.tolist(), ts.tolist()
        self.binlog.extend(
            (table, kl[i], tl[i],
             {c: float(cols[c][i]) for c in cols}) for i in range(n))
        self._binlog_offset += n
        return off

    def evict(self, table: str, horizon_ts: int):
        """Batch TTL eviction + slot compaction (one pass, §7.2)."""
        self.tables[table] = evict_before(self.tables[table],
                                          jnp.int32(horizon_ts))

    def n_rows(self, table: str) -> int:
        return int(self.tables[table]["count"])

    def snapshot(self) -> StoreSnapshot:
        """Cut an immutable point-in-time read view (O(#tables))."""
        return StoreSnapshot(self)


class ShardedOnlineStore(_BinlogMixin):
    """Key-sharded online store: the paper's tablet partitioning (§5, §7.2)
    mapped onto a ``jax.sharding.Mesh`` axis.

    Layout: every per-table ``StoreState`` leaf gains a leading shard dim —
    ``keys: (n_shards, capacity)`` etc. — and *all rows of a given
    partition key live on exactly one shard*, so window folds over a key
    never cross shards (the locality invariant the paper's key-partitioned
    workers rely on; arXiv:2305.20077 makes the same argument at
    datacenter scale).  With ``mesh`` given, the stacked pytree is placed
    one-shard-per-device and the query path runs under ``shard_map``
    (``CompiledScript.online_sharded_batch``); with ``mesh=None`` the same
    stacked layout runs as a vmap over logical shards on one device —
    bit-identical results either way.

    Routing: key -> route slot (splitmix64 hash mod ``n_route_slots``) ->
    shard (host-side assignment table).  The table starts as the static
    hash baseline and is recomputed from observed per-slot load by
    ``core.union.LoadBalancer`` greedy LPT on ``rebalance()``, which also
    migrates resident rows to their new owners.  Keys are always moved
    *whole* (LoadBalancer's hot-key splitting is not used here: splitting
    one key's rows across shards would break the ordered-fold locality
    that makes sharded results bit-exact).

    ``capacity`` is PER SHARD: total resident rows = n_shards * capacity,
    and a skewed key distribution needs per-shard headroom.

    Replication (``storage.replication``): slot s of the stacked layout
    is shard s's LEADER; ``shard_state``/``install_shard``/``wipe_shard``
    expose the per-shard slices follower replicas are seeded from and
    promoted into, and the binlog (every entry carries table, key, ts
    AND values) is the shipping stream that keeps followers bitwise
    convergent with their leader.
    """

    def __init__(self, capacity: int, n_shards: Optional[int] = None,
                 mesh=None, axis: str = "shard",
                 n_route_slots: int = 1024):
        from ..core.union import LoadBalancer

        if mesh is not None:
            if axis not in mesh.shape:
                raise ValueError(f"mesh has no axis {axis!r}")
            mesh_n = mesh.shape[axis]
            if n_shards is not None and n_shards != mesh_n:
                raise ValueError(f"n_shards={n_shards} != mesh axis "
                                 f"{axis!r} size {mesh_n}")
            n_shards = mesh_n
        if not n_shards or n_shards < 1:
            raise ValueError("need n_shards >= 1 or a mesh")
        self.capacity = capacity
        self.n_shards = int(n_shards)
        self.mesh = mesh
        self.axis = axis
        self.n_route_slots = n_route_slots
        # split_threshold=inf: hot-slot splitting must stay OFF so LPT's
        # load accounting matches the whole-key placement rebalance()
        # actually performs (see class docstring)
        self.balancer = LoadBalancer(n_route_slots, self.n_shards,
                                     split_threshold=float("inf"))
        self.assignment = self.balancer.assignment.copy()
        self._slot_counts = np.zeros(n_route_slots, np.float64)
        self.tables: Dict[str, StoreState] = {}
        self.col_specs: Dict[str, Dict[str, jnp.dtype]] = {}
        self.binlog: List[Tuple[str, int, int, Dict[str, float]]] = []
        self._binlog_offset = 0
        self._binlog_base = 0
        self.n_rebalances = 0

    # ----------------------------------------------------------- routing
    def route_slots(self, keys) -> np.ndarray:
        """Key -> route slot (hash-bounded key universe for balancing)."""
        from ..core.hll import splitmix64

        k = np.atleast_1d(np.asarray(keys)).astype(np.uint64)
        return (splitmix64(k) % np.uint64(self.n_route_slots)).astype(
            np.int64)

    def owner_of_keys(self, keys) -> np.ndarray:
        """Key -> owning shard under the current assignment."""
        return self.assignment[self.route_slots(keys)].astype(np.int64)

    # ------------------------------------------------------------ tables
    def _place(self, state: StoreState) -> StoreState:
        if self.mesh is None:
            return state
        from ..distributed.sharding import stacked_store_sharding

        return jax.device_put(state,
                              stacked_store_sharding(self.mesh, self.axis))

    def create_table(self, name: str, col_specs: Dict[str, jnp.dtype]):
        base = make_state(self.capacity, col_specs)
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (self.n_shards,) + x.shape),
            base)
        self.tables[name] = self._place(stacked)
        self.col_specs[name] = dict(col_specs)

    def n_rows_per_shard(self, table: str) -> np.ndarray:
        return np.asarray(self.tables[table]["count"])

    def n_rows(self, table: str) -> int:
        return int(self.n_rows_per_shard(table).sum())

    # ------------------------------------------------------------ ingest
    def put(self, table: str, key: int, ts: int,
            values: Dict[str, float]) -> int:
        """Single-row insert: a 1-row ``put_many`` (same routing path)."""
        cols = {c: np.asarray([v], np.float32) for c, v in values.items()}
        return self.put_many(table, np.asarray([key], np.int32),
                             np.asarray([ts], np.int32), cols)

    def put_many(self, table: str, keys, ts,
                 cols: Dict[str, "np.ndarray"]) -> int:
        """Bulk insert routed by key: rows are grouped per owning shard
        (arrival order preserved within a shard) and merged with ONE
        vmapped sort-merge across all shards (``insert_many_stacked``).
        Non-owned slots of each shard's block carry INT_MAX padding, so
        they sort into the dead tail exactly like capacity padding.
        """
        keys = np.asarray(keys, np.int32)
        ts = np.asarray(ts, np.int32)
        n = keys.shape[0]
        if n == 0:
            return self._binlog_offset
        slots = self.route_slots(keys)
        owner = self.assignment[slots]
        counts = np.bincount(owner, minlength=self.n_shards)
        live = self.n_rows_per_shard(table)
        over = np.flatnonzero(live + counts > self.capacity)
        if over.size:
            s = int(over[0])
            raise ValueError(
                f"bulk put overflows shard {s}: {int(live[s])} live + "
                f"{int(counts[s])} new > per-shard capacity "
                f"{self.capacity}")
        m = next_pow2(int(max(1, counts.max())))
        k_blk = np.full((self.n_shards, m), INT_MAX, np.int32)
        t_blk = np.full((self.n_shards, m), INT_MAX, np.int32)
        pos = np.empty(n, np.int64)
        for s in range(self.n_shards):
            sel = np.flatnonzero(owner == s)
            pos[sel] = np.arange(sel.size)
        k_blk[owner, pos] = keys
        t_blk[owner, pos] = ts
        vals = {}
        for name, dtype in self.col_specs[table].items():
            v = np.zeros((self.n_shards, m), dtype)
            if name in cols:
                v[owner, pos] = np.asarray(cols[name], dtype)
            vals[name] = jnp.asarray(v)
        self.tables[table] = insert_many_stacked(
            self.tables[table], jnp.asarray(k_blk), jnp.asarray(t_blk),
            vals, jnp.asarray(counts, jnp.int32))
        self._slot_counts += np.bincount(slots,
                                         minlength=self.n_route_slots)
        off = self._binlog_offset
        kl, tl = keys.tolist(), ts.tolist()
        self.binlog.extend(
            (table, kl[i], tl[i],
             {c: float(cols[c][i]) for c in cols}) for i in range(n))
        self._binlog_offset += n
        return off

    def bulk_load(self, table: str, keys, ts, cols: Dict[str, "np.ndarray"]
                  ) -> int:
        """LOAD DATA: route once, sort each shard once, overwrite."""
        keys = np.asarray(keys, np.int32)
        ts = np.asarray(ts, np.int32)
        n = keys.shape[0]
        arrival = np.arange(n)
        slots = self.route_slots(keys)
        owner = self.assignment[slots]
        state = self._build_state(table, keys, ts,
                                  {c: np.asarray(cols[c]) for c in
                                   self.col_specs[table] if c in cols},
                                  owner, arrival)
        self.tables[table] = state
        # after _build_state: a per-shard overflow must not leave
        # phantom load in the balancer (put_many orders the same way)
        self._slot_counts += np.bincount(slots,
                                         minlength=self.n_route_slots)
        order = np.lexsort((arrival, ts, keys))
        ko, tso = keys[order].tolist(), ts[order].tolist()
        # full-fidelity entries: a log replay must rebuild values too
        # (see OnlineStore.bulk_load / storage.replication)
        co = {c: np.asarray(cols[c])[order].tolist() for c in cols}
        self.binlog.extend(
            (table, ko[i], tso[i], {c: float(co[c][i]) for c in co})
            for i in range(n))
        self._binlog_offset += n
        return n

    def _build_state(self, table: str, keys, ts, cols, owner, arrival
                     ) -> StoreState:
        """Stacked state from host rows: per-shard (key, ts, arrival)
        lexsort — the same order per-shard sequential inserts produce."""
        counts = np.bincount(owner, minlength=self.n_shards)
        if counts.max(initial=0) > self.capacity:
            s = int(np.argmax(counts))
            raise ValueError(f"shard {s} gets {int(counts[s])} rows > "
                             f"per-shard capacity {self.capacity}")
        specs = self.col_specs[table]
        k_st = np.full((self.n_shards, self.capacity), INT_MAX, np.int32)
        t_st = np.full((self.n_shards, self.capacity), INT_MAX, np.int32)
        c_st = {c: np.zeros((self.n_shards, self.capacity), dt)
                for c, dt in specs.items()}
        for s in range(self.n_shards):
            sel = np.flatnonzero(owner == s)
            if not sel.size:
                continue
            order = sel[np.lexsort((arrival[sel], ts[sel], keys[sel]))]
            k_st[s, :order.size] = keys[order]
            t_st[s, :order.size] = ts[order]
            for c in c_st:
                if c in cols:
                    c_st[c][s, :order.size] = np.asarray(cols[c])[order]
        return self._place({
            "keys": jnp.asarray(k_st),
            "ts": jnp.asarray(t_st),
            "cols": {c: jnp.asarray(v) for c, v in c_st.items()},
            "count": jnp.asarray(counts, jnp.int32),
        })

    def evict(self, table: str, horizon_ts: int):
        """Per-shard batch TTL eviction + slot compaction (vmapped)."""
        self.tables[table] = evict_before_stacked(self.tables[table],
                                                  jnp.int32(horizon_ts))

    # --------------------------------------------------------- rebalance
    def rebalance(self) -> bool:
        """Hot-key rebalancing (§5.2 mapped to shards): fold accumulated
        per-slot load into the LoadBalancer EMA, recompute the slot->shard
        map with greedy LPT, and migrate resident rows whose owner
        changed.  Whole-key moves only (see class docstring).  Returns
        True if the assignment changed (callers owning per-shard derived
        state — pre-agg buckets — must migrate it too; see
        ``serve.engine.FeatureEngine.rebalance``).
        """
        self.balancer.observe(self._slot_counts)
        # counts are folded into the EMA exactly once: zero them NOW so a
        # retry after a failed migration doesn't double-count the load
        self._slot_counts[:] = 0.0
        new_assign = self.balancer.rebalance().copy()
        if np.array_equal(new_assign, self.assignment):
            return False
        # two-phase: build EVERY table's migrated state before committing
        # anything — a per-shard capacity overflow mid-migration must not
        # leave some tables routed by the new assignment while
        # self.assignment still routes by the old one
        new_tables: Dict[str, StoreState] = {}
        for table in self.tables:
            st = jax.device_get(self.tables[table])
            counts = np.asarray(st["count"])
            rows_k, rows_t, rows_c, rows_pos = [], [], {c: [] for c in
                                                        st["cols"]}, []
            for s in range(self.n_shards):
                c = int(counts[s])
                rows_k.append(np.asarray(st["keys"][s, :c]))
                rows_t.append(np.asarray(st["ts"][s, :c]))
                for col in rows_c:
                    rows_c[col].append(np.asarray(st["cols"][col][s, :c]))
                # global source position: preserves per-key arrival order
                # (all rows of one key live on one source shard)
                rows_pos.append(s * self.capacity + np.arange(c))
            keys = np.concatenate(rows_k)
            ts = np.concatenate(rows_t)
            cols = {c: np.concatenate(v) for c, v in rows_c.items()}
            pos = np.concatenate(rows_pos)
            owner = new_assign[self.route_slots(keys)] if keys.size else \
                np.zeros(0, np.int64)
            new_tables[table] = self._build_state(table, keys, ts, cols,
                                                  owner, pos)
        self.tables.update(new_tables)
        self.assignment = new_assign
        self.n_rebalances += 1
        return True

    # ------------------------------------------------------- replication
    # Reads always go to the leader slot: slot s of the stacked pytree IS
    # shard s's leader replica, and the serving path
    # (``online_sharded_batch``) only ever gathers from it.  Follower
    # replicas live OUTSIDE the stacked layout (storage.replication) and
    # enter it exclusively through ``install_shard`` at promotion.

    def shard_state(self, table: str, shard: int) -> StoreState:
        """Unstacked copy of one shard's slice of ``table`` — the
        leader's state, used to seed/resync follower replicas."""
        return jax.tree_util.tree_map(lambda x: x[shard],
                                      self.tables[table])

    def install_shard(self, shard: int,
                      tables: Dict[str, StoreState]) -> None:
        """Write per-shard states into stacked slot ``shard`` (follower
        promotion: the promoted replica becomes the leader for the
        shard's key range; routing is untouched — key -> slot stays,
        only the slot's contents are replaced)."""
        for name, st in tables.items():
            # through host memory: a scatter into a mesh-placed stacked
            # array with a replicated index has incompatible shardings,
            # and promotion is a cold-path host operation anyway
            def _put(full, part):
                out = np.asarray(jax.device_get(full)).copy()
                out[shard] = np.asarray(jax.device_get(part), out.dtype)
                return jnp.asarray(out)

            stacked = jax.tree_util.tree_map(_put, self.tables[name], st)
            self.tables[name] = self._place(stacked)

    def snapshot(self) -> StoreSnapshot:
        """Cut an immutable point-in-time read view: frozen tables AND
        frozen routing (see ``StoreSnapshot``)."""
        return StoreSnapshot(self)

    def wipe_shard(self, shard: int) -> None:
        """Fault injection: shard ``shard`` loses all resident rows (the
        dense analogue of a tablet node dying — its slot reads as an
        empty, freshly-provisioned store until a replica is promoted
        into it)."""
        empty = {name: make_state(self.capacity, self.col_specs[name])
                 for name in self.tables}
        self.install_shard(shard, empty)
