"""Online time-series store — TPU-native adaptation of the refined skiplist.

The paper's §7.2 structure is a two-level skiplist: level 1 sorted by key,
level 2 per-key linked lists sorted by timestamp, with lock-free CAS
inserts and batch TTL eviction.  Pointer-chasing has no TPU analogue, so we
keep the *invariant* (data pre-ranked by (key, ts) so online access is a
seek + contiguous scan) in a dense representation:

    keys : (capacity,) int32   sorted ascending; padding = INT32_MAX
    ts   : (capacity,) int32   sorted within each key run; padding = MAX
    cols : {name: (capacity,) float32/int32}
    count: ()        int32     live rows

All operations are pure jax (jit-able, static shapes):

  * ``insert``       O(capacity) vectorized shift (a write is a roll of the
                     suffix — fully parallel on a vector unit, unlike a CAS
                     chain, and single-writer per shard matches the paper's
                     replicator-lock serialization anyway),
  * ``range_bounds`` O(log capacity) via branchless binary search,
  * ``evict_before`` batch TTL deletion (§7.2): drop every row with
                     ts < horizon in one compaction pass,
  * a host-side ``binlog`` (insert sequence numbers) drives asynchronous
    pre-aggregation updates exactly like the paper's
    ``replicator->AppendEntry`` (§5.1 Aggregator Update).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INT_MAX = np.int32(2**31 - 1)

__all__ = ["StoreState", "OnlineStore", "insert", "insert_many",
           "range_bounds", "evict_before", "gather_window", "next_pow2"]


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1): batch-size padding that keeps
    jit recompiles logarithmic in batch size."""
    return 1 << max(0, (n - 1).bit_length())

# StoreState is a plain pytree: dict with fixed structure.
StoreState = Dict


def make_state(capacity: int, col_specs: Dict[str, jnp.dtype]) -> StoreState:
    return {
        "keys": jnp.full((capacity,), INT_MAX, jnp.int32),
        "ts": jnp.full((capacity,), INT_MAX, jnp.int32),
        "cols": {name: jnp.zeros((capacity,), dtype)
                 for name, dtype in col_specs.items()},
        "count": jnp.zeros((), jnp.int32),
    }


def _bsearch(keys: jnp.ndarray, tss: jnp.ndarray, key, ts,
             strict: bool) -> jnp.ndarray:
    """Branchless binary search over the (key, ts)-sorted store:
    first index i with (keys[i], ts[i]) > (key, ts)   [strict=True]
    or >= (key, ts)                                    [strict=False].
    O(log capacity) scalar gathers — the dense-array analogue of the
    skiplist seek (§7.2): pre-ranked data makes access logarithmic,
    never a scan."""
    n = keys.shape[0]
    steps = max(1, (n - 1).bit_length() + 1)
    lo = jnp.int32(0)
    hi = jnp.int32(n)

    def body(_, carry):
        lo_, hi_ = carry
        mid = (lo_ + hi_) // 2
        m = jnp.clip(mid, 0, n - 1)
        k_m = keys[m]
        t_m = tss[m]
        if strict:
            gt = (k_m > key) | ((k_m == key) & (t_m > ts))
        else:
            gt = (k_m > key) | ((k_m == key) & (t_m >= ts))
        go_left = gt & (lo_ < hi_)
        hi_ = jnp.where(go_left, mid, hi_)
        lo_ = jnp.where(go_left | (lo_ >= hi_), lo_, mid + 1)
        return lo_, hi_

    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo.astype(jnp.int32)


def insert_pos(state: StoreState, key, ts) -> jnp.ndarray:
    """First index i with (keys[i], ts[i]) > (key, ts): insert *after*
    peers, preserving arrival order among equal timestamps (this is what
    makes online replay bitwise-match the offline stable sort).  Padding
    rows (INT_MAX keys) always compare "after"."""
    pos = _bsearch(state["keys"], state["ts"], key, ts, strict=True)
    return jnp.minimum(pos, state["count"])


@jax.jit
def insert(state: StoreState, key, ts, values: Dict[str, jnp.ndarray]
           ) -> StoreState:
    """Sorted insert of one row (vectorized suffix shift)."""
    pos = insert_pos(state, key, ts)
    idx = jnp.arange(state["keys"].shape[0], dtype=jnp.int32)

    def shifted(arr, new_val):
        prev = jnp.roll(arr, 1)
        out = jnp.where(idx > pos, prev, arr)
        return jnp.where(idx == pos, jnp.asarray(new_val, arr.dtype), out)

    new_cols = {}
    for name, arr in state["cols"].items():
        new_cols[name] = shifted(arr, values.get(name, 0))
    return {
        "keys": shifted(state["keys"], key),
        "ts": shifted(state["ts"], ts),
        "cols": new_cols,
        "count": state["count"] + 1,
    }


@jax.jit
def insert_many(state: StoreState, keys, ts, values: Dict[str, jnp.ndarray],
                n_new) -> StoreState:
    """Sorted insert of a padded batch of rows with ONE merge.

    ``keys``/``ts`` are (M,) int32 with padding rows carrying INT_MAX in
    both; ``n_new`` is the number of real rows.  Cost is one
    O((capacity+M) log) lexsort instead of M O(capacity) suffix shifts —
    the bulk-ingest analogue of the skiplist's batch build.

    Ordering matches M sequential ``insert`` calls: new rows land *after*
    existing peers with equal (key, ts) (existing rows carry smaller
    arrival ranks), and arrival order among the new rows themselves is
    preserved (rank = capacity + j).  Rows sorted beyond ``capacity`` are
    dropped — the host wrapper guarantees they are padding only.
    """
    cap = state["keys"].shape[0]
    m = keys.shape[0]
    rank = jnp.concatenate([jnp.arange(cap, dtype=jnp.int32),
                            cap + jnp.arange(m, dtype=jnp.int32)])
    all_keys = jnp.concatenate([state["keys"], jnp.asarray(keys, jnp.int32)])
    all_ts = jnp.concatenate([state["ts"], jnp.asarray(ts, jnp.int32)])
    perm = jnp.lexsort((rank, all_ts, all_keys))[:cap]

    new_cols = {}
    for name, arr in state["cols"].items():
        v = jnp.asarray(values.get(name, jnp.zeros((m,), arr.dtype)),
                        arr.dtype)
        new_cols[name] = jnp.take(jnp.concatenate([arr, v]), perm, axis=0)
    return {
        "keys": jnp.take(all_keys, perm),
        "ts": jnp.take(all_ts, perm),
        "cols": new_cols,
        "count": state["count"] + jnp.asarray(n_new, jnp.int32),
    }


def range_bounds(state: StoreState, key, t0, t1) -> Tuple[jnp.ndarray,
                                                          jnp.ndarray]:
    """[lo, hi) of rows with keys==key and ts in [t0, t1] (peers at t1
    included — matches the position-based offline semantics when the
    querying row is about to be inserted after its peers).  Two binary
    searches: O(log capacity), independent of table size."""
    keys, tss = state["keys"], state["ts"]
    n = state["count"]
    lo = jnp.minimum(_bsearch(keys, tss, key, t0, strict=False), n)
    hi = jnp.minimum(_bsearch(keys, tss, key, t1, strict=True), n)
    lo = jnp.minimum(lo, hi)
    return lo, hi


@jax.jit
def evict_before(state: StoreState, horizon_ts) -> StoreState:
    """Batch TTL eviction (§7.2): remove all rows with ts < horizon.

    Dense-array equivalent of the skiplist's contiguous-head deletion:
    one stable compaction (keep-mask prefix sum + scatter).
    """
    keys, tss = state["keys"], state["ts"]
    cap = keys.shape[0]
    idx = jnp.arange(cap, dtype=jnp.int32)
    live = idx < state["count"]
    keep = live & (tss >= horizon_ts)
    dest = jnp.cumsum(keep.astype(jnp.int32)) - 1
    # out-of-bounds destinations are dropped by the scatter
    scatter_to = jnp.where(keep, dest, cap)

    def compact(arr, fill):
        out = jnp.full_like(arr, fill)
        return out.at[scatter_to].set(arr, mode="drop")

    new_cols = {k: compact(v, 0) for k, v in state["cols"].items()}
    return {
        "keys": compact(keys, INT_MAX),
        "ts": compact(tss, INT_MAX),
        "cols": new_cols,
        "count": jnp.sum(keep.astype(jnp.int32)),
    }


def gather_window(state: StoreState, lo: jnp.ndarray, hi: jnp.ndarray,
                  max_rows: int, col_names: List[str]
                  ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray,
                             jnp.ndarray]:
    """Gather the newest ``max_rows`` rows of [lo, hi) into fixed buffers.

    Returns (cols, ts, valid).  Rows are in time order; if the range holds
    more than ``max_rows`` rows only the most recent are kept (the same
    truncation MAXSIZE applies to windows).
    """
    start = jnp.maximum(lo, hi - max_rows)
    base = jnp.arange(max_rows, dtype=jnp.int32)
    idx = start + base
    valid = idx < hi
    safe = jnp.clip(idx, 0, state["keys"].shape[0] - 1)
    cols = {c: jnp.take(state["cols"][c], safe, axis=0)
            for c in col_names}
    ts = jnp.take(state["ts"], safe, axis=0)
    return cols, ts, valid


class OnlineStore:
    """Host-facing wrapper: one StoreState per table + a binlog.

    The binlog (monotone offsets, host side) decouples pre-aggregation
    updates from the insert path, mirroring §5.1's asynchronous
    ``update_aggr`` closures: consumers (PreAggregator) read the log tail
    and fold new rows into their buckets.
    """

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.tables: Dict[str, StoreState] = {}
        self.col_specs: Dict[str, Dict[str, jnp.dtype]] = {}
        self.binlog: List[Tuple[str, int, int, Dict[str, float]]] = []
        self._binlog_offset = 0

    def create_table(self, name: str, col_specs: Dict[str, jnp.dtype]):
        self.tables[name] = make_state(self.capacity, col_specs)
        self.col_specs[name] = dict(col_specs)

    def bulk_load(self, table: str, keys, ts, cols: Dict[str, "np.ndarray"]
                  ) -> int:
        """LOAD DATA path: sort once by (key, ts, arrival) and overwrite
        the table state (paper Figure 3's offline->online sync)."""
        keys = np.asarray(keys, np.int32)
        ts = np.asarray(ts, np.int32)
        n = keys.shape[0]
        if n > self.capacity:
            raise ValueError(f"bulk load of {n} rows exceeds capacity "
                             f"{self.capacity}")
        order = np.lexsort((np.arange(n), ts, keys))
        st = make_state(self.capacity, self.col_specs[table])
        st["keys"] = st["keys"].at[:n].set(jnp.asarray(keys[order]))
        st["ts"] = st["ts"].at[:n].set(jnp.asarray(ts[order]))
        for name in st["cols"]:
            arr = np.asarray(cols[name])[order]
            st["cols"][name] = st["cols"][name].at[:n].set(
                jnp.asarray(arr, st["cols"][name].dtype))
        st["count"] = jnp.asarray(n, jnp.int32)
        self.tables[table] = st
        ko = keys[order].tolist()
        tso = ts[order].tolist()
        self.binlog.extend((table, ko[i], tso[i], {}) for i in range(n))
        self._binlog_offset += n
        return n

    def put(self, table: str, key: int, ts: int,
            values: Dict[str, float]) -> int:
        """Insert + append to binlog; returns the binlog offset."""
        st = self.tables[table]
        self.tables[table] = insert(st, jnp.int32(key), jnp.int32(ts),
                                    {k: jnp.asarray(v) for k, v in
                                     values.items()})
        off = self._binlog_offset
        self.binlog.append((table, int(key), int(ts), dict(values)))
        self._binlog_offset += 1
        return off

    def put_many(self, table: str, keys, ts,
                 cols: Dict[str, "np.ndarray"]) -> int:
        """Bulk insert of N rows with one sort-merge (vs N O(capacity)
        shifts for sequential ``put``); returns the first binlog offset.

        Equivalent to ``put``-ing the rows in order: rows are appended to
        the binlog in arrival order and land after existing (key, ts)
        peers in the store.  Batches are padded to the next power of two
        so jit recompiles stay logarithmic in batch size.
        """
        keys = np.asarray(keys, np.int32)
        ts = np.asarray(ts, np.int32)
        n = keys.shape[0]
        if n == 0:
            return self._binlog_offset
        if self.n_rows(table) + n > self.capacity:
            raise ValueError(f"bulk put of {n} rows overflows capacity "
                             f"{self.capacity}")
        m = next_pow2(n)
        k_pad = np.full((m,), INT_MAX, np.int32)
        t_pad = np.full((m,), INT_MAX, np.int32)
        k_pad[:n] = keys
        t_pad[:n] = ts
        specs = self.col_specs[table]
        vals = {}
        for name, dtype in specs.items():
            v = np.zeros((m,), dtype)
            if name in cols:
                v[:n] = np.asarray(cols[name], dtype)
            vals[name] = jnp.asarray(v)
        self.tables[table] = insert_many(
            self.tables[table], jnp.asarray(k_pad), jnp.asarray(t_pad),
            vals, n)
        off = self._binlog_offset
        kl, tl = keys.tolist(), ts.tolist()
        self.binlog.extend(
            (table, kl[i], tl[i],
             {c: float(cols[c][i]) for c in cols}) for i in range(n))
        self._binlog_offset += n
        return off

    def read_binlog(self, from_offset: int):
        return self.binlog[from_offset:], self._binlog_offset

    def evict(self, table: str, horizon_ts: int):
        self.tables[table] = evict_before(self.tables[table],
                                          jnp.int32(horizon_ts))

    def n_rows(self, table: str) -> int:
        return int(self.tables[table]["count"])
