"""Per-shard replication, failover, and bitwise recovery for the
sharded serving path (paper §5 deployment: replicated tablets).

Every shard of a ``ShardedOnlineStore`` gets R FOLLOWER replicas placed
on distinct mesh devices.  The leader (stacked slot s — the only replica
the serving path ever reads) applies writes and the store binlog is the
shipping stream: ``ReplicationManager.ship`` reads each follower's
unacked log tail, filters it to the shard's key range, and applies it
through the SAME ordered ``insert_many`` path the leader ran —
``insert_many`` of any batching of a row sequence equals the sequential
inserts, so a fully-shipped follower is **bitwise identical** to its
leader, not approximately in sync.  ``ReplicationLog`` tracks
per-follower acked offsets and replication lag.

Failure handling is split the same way the paper splits it:

  * ``FailoverController`` (driving ``distributed.fault.HeartbeatMonitor``
    with shards as hosts) detects a dead shard, promotes its
    most-caught-up follower (``distributed.fault.most_caught_up``),
    replays the follower's unacked binlog tail, and installs the result
    into the leader slot (``ShardedOnlineStore.install_shard``) —
    routing is untouched, serving resumes bitwise-identically.
  * Cold recovery (no live follower) is checkpoint-restore + binlog
    replay: ``cold_recover_shard`` restores the shard's slices from a
    ``distributed.fault.CheckpointManager`` snapshot cut at a binlog
    watermark and replays the tail past the watermark.  Pre-aggregation
    bucket planes recover the same way (``recover_preagg_shard`` +
    ``PreAgg.restore_shard_plane``) from the consumed-offset watermark.

Consistency barriers: shipping replays *puts* only, so any operation
that mutates leader state outside the log — ``bulk_load`` (whole-state
overwrite), ``rebalance`` (ownership change), TTL eviction — must be a
barrier.  ``ReplicationManager.resync`` re-seeds followers from leader
slices (bulk_load / rebalance), and ``evict`` ships every follower to
the log head first, then applies the identical eviction pass to each
(``serve.engine.FeatureEngine`` calls it on the scheduled compaction
tick).  Binlog truncation must never pass ``safe_offset()`` — the
minimum follower acked offset — or a lagging follower could no longer
be caught up (``FeatureEngine`` clamps its truncation watermark to it).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.fault import (CheckpointManager, HeartbeatMonitor,
                                 most_caught_up)
from .timestore import (INT_MAX, ShardedOnlineStore, StoreState,
                        evict_before, insert_many, make_state, next_pow2)

__all__ = ["ReplicationLog", "ReplicationManager", "FailoverController",
           "PromotionRecord", "apply_entries", "cold_recover_shard",
           "recover_preagg_shard"]

# a binlog entry: (table, key, ts, {col: value})
Entry = Tuple[str, int, int, Dict[str, float]]


class ReplicationLog:
    """Per-(shard, follower) acked offsets over the store's binlog.

    Offsets are ABSOLUTE binlog offsets (stable across truncation);
    ``acked[s, r]`` is the offset through which follower r of shard s
    has applied every entry owned by shard s.  Lag is measured in log
    entries — the unit the failover replay actually pays for.
    """

    def __init__(self, n_shards: int, n_replicas: int):
        self.n_shards = int(n_shards)
        self.n_replicas = int(n_replicas)
        self.acked = np.zeros((n_shards, n_replicas), np.int64)

    def ack(self, shard: int, replica: int, offset: int) -> None:
        self.acked[shard, replica] = max(self.acked[shard, replica],
                                         int(offset))

    def lag(self, leader_offset: int) -> np.ndarray:
        """(n_shards, n_replicas) entries each follower is behind."""
        return np.maximum(0, int(leader_offset) - self.acked)

    def max_lag(self, leader_offset: int) -> int:
        return int(self.lag(leader_offset).max(initial=0))

    def safe_offset(self) -> int:
        """Truncation low-watermark: the binlog below min(acked) has
        been applied by EVERY follower and may be dropped."""
        return int(self.acked.min())

    def most_caught_up(self, shard: int) -> int:
        """Promotion choice for one shard (distributed.fault policy)."""
        return most_caught_up(
            {r: int(self.acked[shard, r])
             for r in range(self.n_replicas)})


@dataclasses.dataclass
class PromotionRecord:
    """What one failover did (recovery/lag stats surface)."""

    shard: int
    replica: int
    acked_at_promotion: int        # follower offset before tail replay
    replayed_entries: int          # unacked tail applied at promotion
    recovery_s: float


def _table_runs(entries: Sequence[Entry]):
    """Maximal runs of consecutive same-table entries, order preserved.

    Batching per run (not per table globally) keeps the cross-table
    interleaving intact — a UNION window's pre-agg buckets fold rows of
    several tables into one (key, bucket) slot, so reordering across
    tables would change order-sensitive combines.
    """
    i, n = 0, len(entries)
    while i < n:
        j = i
        table = entries[i][0]
        while j < n and entries[j][0] == table:
            j += 1
        run = entries[i:j]
        keys = np.asarray([e[1] for e in run], np.int32)
        ts = np.asarray([e[2] for e in run], np.int32)
        cols: Dict[str, np.ndarray] = {}
        for c in {c for e in run for c in e[3]}:
            cols[c] = np.asarray([e[3].get(c, 0.0) for e in run],
                                 np.float32)
        yield table, keys, ts, cols
        i = j


def apply_entries(tables: Dict[str, StoreState],
                  col_specs: Dict[str, Dict[str, Any]],
                  entries: Sequence[Entry]) -> Dict[str, StoreState]:
    """Apply binlog entries to per-shard (unstacked) states through the
    one ordered ``insert_many`` merge — the identical code path the
    leader's routed ``put_many`` ran, so the result is bitwise equal to
    the leader's slice no matter how the entries are re-batched."""
    for table, keys, ts, cols in _table_runs(entries):
        n = keys.shape[0]
        m = next_pow2(n)
        k_pad = np.full((m,), INT_MAX, np.int32)
        t_pad = np.full((m,), INT_MAX, np.int32)
        k_pad[:n] = keys
        t_pad[:n] = ts
        vals = {}
        for name, dtype in col_specs[table].items():
            v = np.zeros((m,), dtype)
            if name in cols:
                v[:n] = np.asarray(cols[name], dtype)
            vals[name] = jnp.asarray(v)
        tables[table] = insert_many(tables[table], jnp.asarray(k_pad),
                                    jnp.asarray(t_pad), vals, n)
    return tables


@dataclasses.dataclass
class _Follower:
    replica: int
    device: Optional[Any]
    tables: Dict[str, StoreState]


class ReplicationManager:
    """R follower replicas per shard, fed from the store binlog.

    Followers live outside the serving layout, on devices distinct from
    their leader's when a mesh is present (``(s + 1 + r) % n_devices`` —
    a node loss never takes a shard and all its replicas together).
    """

    def __init__(self, store: ShardedOnlineStore, n_replicas: int = 1):
        if n_replicas < 1:
            raise ValueError("replication needs >= 1 follower per shard")
        self.store = store
        self.n_replicas = int(n_replicas)
        self.log = ReplicationLog(store.n_shards, n_replicas)
        self.followers: Dict[Tuple[int, int], _Follower] = {}
        self._devices = (list(store.mesh.devices.flat)
                         if store.mesh is not None else [])
        for s in range(store.n_shards):
            for r in range(n_replicas):
                dev = (self._devices[(s + 1 + r) % len(self._devices)]
                       if self._devices else None)
                self.followers[(s, r)] = _Follower(r, dev, {})
        self.n_shipped = 0
        self.max_lag_seen = 0
        self._ensure_tables()

    # ------------------------------------------------------------ state
    def _ensure_tables(self) -> None:
        """Provision empty follower states for any store table missing
        one (tables created after the manager attaches included)."""
        for name, specs in self.store.col_specs.items():
            for f in self.followers.values():
                if name not in f.tables:
                    st = make_state(self.store.capacity, specs)
                    f.tables[name] = (jax.device_put(st, f.device)
                                      if f.device is not None else st)

    def _observe_lag(self) -> None:
        self.max_lag_seen = max(self.max_lag_seen,
                                self.log.max_lag(self.store._binlog_offset))

    # ------------------------------------------------------------- ship
    def ship(self, shard: Optional[int] = None,
             replica: Optional[int] = None) -> int:
        """Ship the unacked binlog tail to followers (async replication
        tick).  Returns the number of entries applied.  Each follower
        reads from its OWN acked offset, filters the tail to its shard's
        key range under the current assignment, and applies it through
        ``apply_entries``; acked offsets advance to the log head."""
        self._ensure_tables()
        self._observe_lag()
        applied = 0
        shards = range(self.store.n_shards) if shard is None else [shard]
        for s in shards:
            replicas = (range(self.n_replicas) if replica is None
                        else [replica])
            for r in replicas:
                f = self.followers[(s, r)]
                frm = int(self.log.acked[s, r])
                entries, end = self.store.read_binlog(frm)
                if entries:
                    keys = np.asarray([e[1] for e in entries])
                    own = self.store.owner_of_keys(keys) == s
                    mine = [e for e, o in zip(entries, own) if o]
                    if mine:
                        apply_entries(f.tables, self.store.col_specs,
                                      mine)
                        applied += len(mine)
                self.log.ack(s, r, end)
        self.n_shipped += applied
        return applied

    def resync(self, shard: Optional[int] = None) -> None:
        """Re-seed followers from the leader slices and ack them to the
        log head.  The barrier for every leader mutation that bypasses
        the binlog: ``bulk_load`` (state overwrite — replaying the full
        log would resurrect pre-load rows), ``rebalance`` (the
        ownership filter changed under shipped history), and follower
        (re)provisioning after a promotion."""
        self._ensure_tables()
        end = self.store._binlog_offset
        shards = range(self.store.n_shards) if shard is None else [shard]
        for s in shards:
            for r in range(self.n_replicas):
                f = self.followers[(s, r)]
                for name in self.store.tables:
                    st = self.store.shard_state(name, s)
                    f.tables[name] = (jax.device_put(st, f.device)
                                      if f.device is not None else st)
                self.log.acked[s, r] = end

    def evict(self, table: str, horizon_ts: int) -> None:
        """Mirror a leader TTL eviction on every follower.  Callers must
        ``ship()`` first (the engine's compaction tick does): evicting a
        lagging follower out of log order could drop a not-yet-applied
        late row on the leader but keep it on the follower."""
        for f in self.followers.values():
            f.tables[table] = evict_before(f.tables[table],
                                           jnp.int32(horizon_ts))

    # -------------------------------------------------------- promotion
    def promote(self, shard: int) -> Tuple[int, int, Dict[str, StoreState]]:
        """Promote the most-caught-up follower of a dead shard: replay
        its unacked binlog tail (same ordered apply path), return
        (replica, acked_before_replay, tables).  The caller installs the
        tables into the leader slot and then ``resync(shard)``s so the
        promoted follower's old slot becomes a fresh replica of the new
        leader."""
        r = self.log.most_caught_up(shard)
        acked_before = int(self.log.acked[shard, r])
        self.ship(shard=shard, replica=r)   # replay the unacked tail
        return r, acked_before, self.followers[(shard, r)].tables

    def stats(self) -> Dict[str, Any]:
        end = self.store._binlog_offset
        return {
            "n_replicas": self.n_replicas,
            "leader_offset": end,
            "acked": self.log.acked.tolist(),
            "lag_entries": self.log.lag(end).tolist(),
            "max_lag_entries": self.log.max_lag(end),
            "max_lag_seen": max(self.max_lag_seen,
                                self.log.max_lag(end)),
            "safe_offset": self.log.safe_offset(),
            "n_shipped": self.n_shipped,
        }


class FailoverController:
    """Detect dead shards and drive promotion.

    Shards are the ``HeartbeatMonitor``'s hosts: every live shard beats
    on serving-path activity, a shard whose beats lapse past the timeout
    (or is explicitly ``mark_dead``-ed by fault injection) is failed
    over — promote its most-caught-up follower, replay the unacked
    tail, install into the leader slot, re-provision the follower.
    """

    def __init__(self, manager: ReplicationManager,
                 timeout_s: float = 5.0,
                 monitor: Optional[HeartbeatMonitor] = None,
                 now: Optional[float] = None):
        self.manager = manager
        self.monitor = monitor or HeartbeatMonitor(
            manager.store.n_shards, timeout_s=timeout_s)
        self._killed: set = set()
        self.records: List[PromotionRecord] = []
        for s in range(manager.store.n_shards):
            self.monitor.beat(s, now=now)      # provision = register

    def beat(self, shard: Optional[int] = None,
             now: Optional[float] = None) -> None:
        """Heartbeat one shard (or every non-killed shard)."""
        shards = (range(self.manager.store.n_shards) if shard is None
                  else [shard])
        for s in shards:
            if s not in self._killed:
                self.monitor.beat(s, now=now)

    def mark_dead(self, shard: int) -> None:
        self._killed.add(shard)

    def dead_shards(self, now: Optional[float] = None) -> List[int]:
        dead = set(self.monitor.dead(now=now)) | self._killed
        return sorted(dead)

    def failover(self, shard: int,
                 now: Optional[float] = None) -> PromotionRecord:
        """Promote + install + re-provision for one dead shard."""
        t0 = time.perf_counter()
        replica, acked_before, tables = self.manager.promote(shard)
        self.manager.store.install_shard(shard, tables)
        self.manager.resync(shard)             # fresh replicas of the
        self._killed.discard(shard)            # ...new leader
        self.monitor.beat(shard, now=now)
        rec = PromotionRecord(
            shard=shard, replica=replica,
            acked_at_promotion=acked_before,
            replayed_entries=self.manager.store._binlog_offset
            - acked_before,
            recovery_s=time.perf_counter() - t0)
        self.records.append(rec)
        return rec

    def check(self, now: Optional[float] = None) -> List[PromotionRecord]:
        """Fail over every currently-dead shard."""
        return [self.failover(s, now=now)
                for s in self.dead_shards(now=now)]


# --------------------------------------------------------- cold recovery

def cold_recover_shard(store: ShardedOnlineStore,
                       ckpt: CheckpointManager, shard: int,
                       watermark: Optional[int] = None) -> int:
    """Checkpoint-restore + binlog-replay recovery of one shard's store
    slices when NO follower survives: restore every table's stacked
    state from the latest checkpoint (cut at binlog offset ==
    checkpoint step), install shard ``shard``'s slices, then replay the
    binlog tail past the watermark through the same ordered apply path.
    Returns the number of replayed entries.  Bitwise by the same
    argument as follower promotion — checkpoint + ordered log replay IS
    the leader's own history."""
    step = watermark if watermark is not None else ckpt.latest_step()
    restored = ckpt.restore({t: store.tables[t] for t in store.tables},
                            step=step)
    slices = {t: jax.tree_util.tree_map(lambda x: jnp.asarray(x)[shard],
                                        restored[t])
              for t in restored}
    entries, _ = store.read_binlog(int(step))
    if entries:
        keys = np.asarray([e[1] for e in entries])
        own = store.owner_of_keys(keys) == shard
        mine = [e for e, o in zip(entries, own) if o]
        if mine:
            apply_entries(slices, store.col_specs, mine)
    else:
        mine = []
    store.install_shard(shard, slices)
    return len(mine)


def recover_preagg_shard(cs, pre_states: Dict[int, Any],
                         snapshot: Dict[int, Any], watermark: int,
                         store: ShardedOnlineStore, shard: int,
                         owned_masks: Dict[int, np.ndarray]
                         ) -> Dict[int, Any]:
    """Recover one shard's pre-aggregation bucket planes from a snapshot
    cut at binlog offset ``watermark``: restore the shard's plane from
    the snapshot (``PreAgg.restore_shard_plane``; other shards' live
    planes untouched), then replay the binlog tail [watermark, end)
    through the SAME ``update_many_sharded`` fold with the ownership
    mask restricted to the recovering shard — every other shard's
    scatter is dropped, and the recovered plane is bitwise equal to the
    lost one (the cur-seeded per-group fold is batch-boundary
    independent)."""
    for wi, w in enumerate(cs.windows):
        if w.preagg is None:
            continue
        pre_states[wi] = w.preagg.restore_shard_plane(
            pre_states[wi], snapshot[wi], shard)
    masks_s = {}
    for wi, m in owned_masks.items():
        m = np.asarray(m)
        only = np.zeros_like(m)
        only[shard] = m[shard]
        masks_s[wi] = only
    entries, _ = store.read_binlog(int(watermark))
    for table, keys, ts, cols in _table_runs(entries):
        pre_states = cs.preagg_update_many_sharded(
            pre_states, table, keys, ts, cols, masks_s)
    return pre_states
