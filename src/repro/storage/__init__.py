"""Compact time-series data management (paper §7, §8)."""

from .timestore import (OnlineStore, ShardedOnlineStore,  # noqa: F401
                        StoreState)
from .encoding import (CompactRowCodec, SparkRowCodec,  # noqa: F401
                       row_size_compact, row_size_spark)
from .memest import estimate_memory, MemoryGuard  # noqa: F401
from .replication import (FailoverController, PromotionRecord,  # noqa: F401
                          ReplicationLog, ReplicationManager,
                          cold_recover_shard, recover_preagg_shard)
