"""Compact in-memory row encoding (paper §7.1) + Spark-style comparison.

Layout (byte-exact reproduction of Figure 5):

    [ header 6B ][ null bitmap ceil(ncols/8)B ][ fixed fields ][ var offsets ][ var data ]

  * header: 1B field version, 1B schema version, 4B (uint32) total row size
  * bitmap: bit i set  <=>  column i is NULL (NULL values not stored)
  * fixed fields: basic types packed contiguously (int 4B, float 4B,
    double/bigint/timestamp 8B, bool 1B); compact offsets are computed
    once per schema (the paper's "more compact offset calculation")
  * var-length fields: per-string *end offset* only (no 32-bit length
    field); string i's length = offset_i - offset_{i-1}.  Offset width is
    the smallest of {1, 2, 4} bytes that can address the var section.

The module also reproduces the §7.1 memory-saving example (20 ints,
20 floats, 20 one-byte strings, 5 timestamps => 255B here vs 556B Spark)
— asserted in tests/test_storage.py.
"""

from __future__ import annotations

import struct
from typing import Any, Dict, List


from ..core.types import ColumnType, TableSchema

__all__ = ["CompactRowCodec", "SparkRowCodec", "row_size_compact",
           "row_size_spark"]

_FIXED_FMT = {
    ColumnType.INT: "<i",
    ColumnType.BIGINT: "<q",
    ColumnType.FLOAT: "<f",
    ColumnType.DOUBLE: "<d",
    ColumnType.TIMESTAMP: "<q",
    ColumnType.BOOL: "<b",
}

HEADER_BYTES = 6


def _offset_width(var_bytes_total: int, n_var: int) -> int:
    """Smallest offset width addressing the var section (paper: avoid a
    fixed 32-bit length per string)."""
    span = var_bytes_total + 1
    if span <= 0xFF:
        return 1
    if span <= 0xFFFF:
        return 2
    return 4


class CompactRowCodec:
    """Encode/decode rows of a schema into the §7.1 compact format."""

    def __init__(self, schema: TableSchema):
        self.schema = schema
        self.n_cols = len(schema.columns)
        self.bitmap_bytes = (self.n_cols + 7) // 8
        # compact fixed-field offsets, computed once per schema
        self.fixed_offsets: Dict[str, int] = {}
        off = 0
        for c in schema.fixed_columns:
            self.fixed_offsets[c.name] = off
            off += c.ctype.fixed_bytes
        self.fixed_bytes = off
        self.var_columns = schema.var_columns

    # -- encode -------------------------------------------------------------
    def encode(self, row: Dict[str, Any], field_version: int = 1,
               schema_version: int = 1) -> bytes:
        nulls = bytearray(self.bitmap_bytes)
        fixed = bytearray(self.fixed_bytes)
        var_payload = bytearray()
        var_ends: List[int] = []

        for i, c in enumerate(self.schema.columns):
            v = row.get(c.name)
            if v is None:
                nulls[i // 8] |= 1 << (i % 8)
                if c.ctype.is_var_length:
                    var_ends.append(len(var_payload))
                continue
            if c.ctype.is_var_length:
                data = v.encode() if isinstance(v, str) else bytes(v)
                var_payload.extend(data)
                var_ends.append(len(var_payload))
            else:
                off = self.fixed_offsets[c.name]
                struct.pack_into(_FIXED_FMT[c.ctype], fixed, off,
                                 _coerce(c.ctype, v))

        ow = _offset_width(len(var_payload), len(self.var_columns))
        offsets = bytearray()
        for end in var_ends:
            offsets.extend(end.to_bytes(ow, "little"))

        size = (HEADER_BYTES + self.bitmap_bytes + len(fixed) +
                len(offsets) + len(var_payload))
        header = struct.pack("<BBI", field_version & 0xFF,
                             schema_version & 0xFF, size)
        return bytes(header + nulls + fixed + offsets + var_payload)

    # -- decode -------------------------------------------------------------
    def decode(self, buf: bytes) -> Dict[str, Any]:
        fv, sv, size = struct.unpack_from("<BBI", buf, 0)
        assert size == len(buf), "row size mismatch"
        pos = HEADER_BYTES
        nulls = buf[pos: pos + self.bitmap_bytes]
        pos += self.bitmap_bytes
        fixed = buf[pos: pos + self.fixed_bytes]
        pos += self.fixed_bytes

        n_var = len(self.var_columns)
        # infer offset width from remaining length: offsets + payload
        remaining = len(buf) - pos
        ow = None
        for cand in (1, 2, 4):
            if n_var * cand <= remaining:
                payload_len = remaining - n_var * cand
                if _offset_width(payload_len, n_var) == cand:
                    ow = cand
        if ow is None:
            ow = 4
        ends = [int.from_bytes(buf[pos + i * ow: pos + (i + 1) * ow],
                               "little") for i in range(n_var)]
        var_base = pos + n_var * ow

        out: Dict[str, Any] = {}
        var_i = 0
        for i, c in enumerate(self.schema.columns):
            is_null = bool(nulls[i // 8] >> (i % 8) & 1)
            if c.ctype.is_var_length:
                if is_null:
                    out[c.name] = None
                else:
                    start = ends[var_i - 1] if var_i > 0 else 0
                    out[c.name] = buf[var_base + start:
                                      var_base + ends[var_i]].decode()
                var_i += 1
            else:
                if is_null:
                    out[c.name] = None
                else:
                    off = self.fixed_offsets[c.name]
                    (v,) = struct.unpack_from(_FIXED_FMT[c.ctype], fixed,
                                              off)
                    out[c.name] = v
        return out

    def row_size(self, row: Dict[str, Any]) -> int:
        return len(self.encode(row))


def _coerce(ctype: ColumnType, v):
    if ctype in (ColumnType.INT, ColumnType.BIGINT, ColumnType.TIMESTAMP):
        return int(v)
    if ctype in (ColumnType.FLOAT, ColumnType.DOUBLE):
        return float(v)
    if ctype is ColumnType.BOOL:
        return int(bool(v))
    return v


class SparkRowCodec:
    """Spark UnsafeRow-style sizing (the paper's comparison baseline):

    8-byte-aligned null-tracking word(s), 8 bytes per fixed field, strings
    8B-rounded data + 8B (offset,length) word.  We reproduce the paper's
    accounting: null set 16B for ~65 cols, every fixed field 8B, string of
    1 byte = 9B (8 data-aligned + 1 metadata... the paper counts 9),
    timestamps 8B.
    """

    def __init__(self, schema: TableSchema):
        self.schema = schema

    def row_size(self, row: Dict[str, Any]) -> int:
        n_cols = len(self.schema.columns)
        null_words = ((n_cols + 63) // 64) * 8
        size = null_words
        for c in self.schema.columns:
            if c.ctype.is_var_length:
                v = row.get(c.name) or ""
                data = v.encode() if isinstance(v, str) else bytes(v)
                size += 8 + len(data)  # 8B offset/len word + payload
            else:
                size += 8
        return size


def row_size_compact(schema: TableSchema, row: Dict[str, Any]) -> int:
    return CompactRowCodec(schema).row_size(row)


def row_size_spark(schema: TableSchema, row: Dict[str, Any]) -> int:
    return SparkRowCodec(schema).row_size(row)
