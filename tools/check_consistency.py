"""CI gate: sharded offline vs sharded online replay consistency.

Runs ``core.consistency.verify_consistency`` on a small synthetic
workload with BOTH executors sharded — offline through
``CompiledScript.offline_sharded`` (itself bit-exact vs the
single-device schedule by construction) and online through the
key-sharded serving path — with pre-aggregation off and on.  Exits
non-zero if any feature drifts outside the consistency contract
(integer features bitwise, floats within reduction-order tolerance).

    PYTHONPATH=src python tools/check_consistency.py [n_shards]
"""

from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.core import compile_script, parse, verify_consistency  # noqa
from repro.data.synthetic import make_action_tables  # noqa

RAW_SQL = """
SELECT sum(price) OVER w AS s, count(price) OVER w AS c,
       max(price) OVER w AS mx, min(price) OVER w AS mn
FROM actions
WINDOW w AS (PARTITION BY userid ORDER BY ts
             ROWS_RANGE BETWEEN 10s PRECEDING AND CURRENT ROW)
"""

PREAGG_SQL = """
SELECT sum(price) OVER w AS s, count(price) OVER w AS c,
       max(price) OVER w AS mx
FROM actions
WINDOW w AS (PARTITION BY userid ORDER BY ts
             ROWS_RANGE BETWEEN 3000s PRECEDING AND CURRENT ROW)
OPTIONS (long_windows = "w:100s")
"""


def main(n_shards: int = 4) -> int:
    ok = True
    tables = make_action_tables(n_actions=150, n_orders=0, n_users=6,
                                seed=11, with_profile=False)
    cs = compile_script(parse(RAW_SQL), tables=tables)
    rep = verify_consistency(cs, tables, n_shards=n_shards)
    print(f"raw       (S={n_shards}): {rep}")
    ok &= rep.passed

    tables2 = make_action_tables(n_actions=120, n_orders=0, n_users=4,
                                 horizon_ms=12_000_000, seed=12,
                                 with_profile=False)
    cs2 = compile_script(parse(PREAGG_SQL), tables=tables2)
    rep2 = verify_consistency(cs2, tables2, use_preagg=True,
                              n_shards=n_shards)
    print(f"preagg    (S={n_shards}): {rep2}")
    ok &= rep2.passed
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(int(sys.argv[1]) if len(sys.argv) > 1 else 4))
