"""CI gate: offline vs online replay consistency, sharded + bitwise.

Runs ``core.consistency.verify_consistency`` on small synthetic
workloads with BOTH executors sharded — offline through
``CompiledScript.offline_sharded`` (itself bit-exact vs the
single-device schedule by construction) and online through the
key-sharded serving path — with pre-aggregation off and on.

The raw gates ALWAYS assert ``array_equal`` on every feature INCLUDING
floats (the one-fold-engine contract: both executors run the same unit
fold core over the same rows — ``verify_consistency``'s default for
raw serving).  ``--bitwise`` additionally runs a pre-agg gate on
integer-valued prices, where bucket-partial re-bracketing is
float-exact, asserting ``array_equal`` there too.  The float-price
pre-agg gate stays at reduction-order tolerance — re-bracketed float
sums are not ULP-stable, by construction of §5.1.

    PYTHONPATH=src python tools/check_consistency.py [--bitwise] [n_shards]
"""

from __future__ import annotations

import sys

try:
    from tools._common import PREAGG_SQL, RAW_SQL, int_prices, tail_int_argv
except ImportError:                      # invoked as `python tools/x.py`
    from _common import PREAGG_SQL, RAW_SQL, int_prices, tail_int_argv

from repro.core import compile_script, parse, verify_consistency  # noqa
from repro.data.synthetic import make_action_tables  # noqa


def main(n_shards: int = 4, bitwise: bool = False) -> int:
    ok = True
    tables = make_action_tables(n_actions=150, n_orders=0, n_users=6,
                                seed=11, with_profile=False)
    cs = compile_script(parse(RAW_SQL), tables=tables)
    rep = verify_consistency(cs, tables, n_shards=n_shards, bitwise=True)
    print(f"raw       (S={n_shards}): {rep}")
    ok &= rep.passed

    # unsharded raw path through the same bitwise gate (same compiled
    # script — the plan and jit caches carry over)
    rep_u = verify_consistency(cs, tables, bitwise=True)
    print(f"raw       (S=1): {rep_u}")
    ok &= rep_u.passed

    tables2 = make_action_tables(n_actions=120, n_orders=0, n_users=4,
                                 horizon_ms=12_000_000, seed=12,
                                 with_profile=False)
    cs2 = compile_script(parse(PREAGG_SQL), tables=tables2)
    rep2 = verify_consistency(cs2, tables2, use_preagg=True,
                              n_shards=n_shards)
    print(f"preagg    (S={n_shards}): {rep2}")
    ok &= rep2.passed

    if bitwise:
        tables3 = int_prices(make_action_tables(
            n_actions=120, n_orders=0, n_users=4,
            horizon_ms=12_000_000, seed=13, with_profile=False))
        cs3 = compile_script(parse(PREAGG_SQL), tables=tables3)
        rep3 = verify_consistency(cs3, tables3, use_preagg=True,
                                  n_shards=n_shards, bitwise=True)
        print(f"preagg-int(S={n_shards}): {rep3}")
        ok &= rep3.passed

        # fused unit-fold megakernel driving BOTH executors (offline
        # blocks + online fast path) through the same bitwise gate
        cs_f = compile_script(parse(RAW_SQL), tables=tables,
                              fused_unit_fold=True)
        rep_f = verify_consistency(cs_f, tables, n_shards=n_shards,
                                   bitwise=True)
        print(f"raw-fused (S={n_shards}): {rep_f}")
        ok &= rep_f.passed
    return 0 if ok else 1


if __name__ == "__main__":
    n, flags = tail_int_argv(None, 4, "--bitwise")
    sys.exit(main(n, bitwise=flags["bitwise"]))
