"""Repo tooling: CI gates, the static certifier CLI, and the lint."""
