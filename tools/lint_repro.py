"""AST lint for the repo's JAX invariants (rule IDs ``J*``).

The last several PRs fixed tracer-purity, donation-safety, and
cache-key bugs by hand; this tool enforces those invariants
mechanically over ``src/``:

``J001`` tracer-unsafe branch
    ``if``/``while`` whose condition derives from a ``jnp.``/``jax.``
    computation: under tracing the condition is a tracer and the
    Python branch either raises or silently bakes in one path.
``J002`` concretization in a traced path
    ``.item()`` / ``float()`` / ``int()`` / ``bool()`` applied to a
    jax-derived value — forces a device sync under eager execution and
    a ConcretizationTypeError under jit.
``J003`` impure call in traced code
    ``time.time``/``perf_counter``/RNG (``np.random``, ``random.*``)
    inside a function that is jitted/vmapped/scanned: the value freezes
    at trace time and silently never changes again.
``J004`` use after donation
    an argument passed at a donated position of a
    ``jax.jit(..., donate_argnums=...)`` function is read again after
    the call — the buffer may already be aliased/invalid.
``J005`` unstable jit-cache key
    an unhashable or iteration-order-dependent component (list/set/dict
    display or constructor, unsorted ``.keys()``/``.values()``) inside
    a key passed to the lowering ``cached(...)``.
``J006`` unused import
    a module-level import never referenced (dead imports hide stale
    dependencies and break doc-path gates late).

Suppression syntax (per line, justification REQUIRED)::

    x = risky()  # lint: ok J001 — host-eager path, never traced

A bare ``# lint: ok J001`` without a justification is itself a finding
(``J000``).  ``# noqa`` / ``# noqa: F401`` on an import line also
suppresses J006 (the conventional re-export marker).

Zero-findings baseline: ``tools/lint_baseline.json`` pins the accepted
finding set (committed empty).  Any finding not in the baseline fails
CI; shrinking the baseline is always allowed.

    python tools/lint_repro.py [paths...] [--json] [--baseline FILE]
"""

from __future__ import annotations

import argparse
import ast
import json
import pathlib
import re
import sys
from typing import Dict, List, Optional, Set, Tuple

RULES = {
    "J000": "suppression without a justification",
    "J001": "Python branch on a jax-derived value",
    "J002": "concretization (.item()/float()/int()/bool()) of a "
            "jax-derived value",
    "J003": "time/RNG call inside traced code",
    "J004": "use of an argument after donation",
    "J005": "unstable component in a jit-cache key",
    "J006": "unused module-level import",
}

JAX_ROOTS = {"jnp", "jax", "lax", "pl", "pltpu"}
# jax.* attributes that return host values / transforms, not tracers
HOST_SIDE_ATTRS = {
    "jit", "vmap", "pmap", "grad", "value_and_grad", "devices",
    "device_count", "local_device_count", "default_backend",
    "named_scope", "checkpoint", "custom_vjp", "custom_jvp",
    "ShapeDtypeStruct", "tree_util", "tree_map", "tree_leaves",
    "make_mesh", "eval_shape", "block_until_ready", "typeof",
    "dtype", "shape", "ndim", "debug",
}
TRACE_ENTRY_ATTRS = {
    "jit", "vmap", "pmap", "grad", "value_and_grad", "checkpoint",
    "scan", "fori_loop", "while_loop", "cond", "switch",
    "associative_scan", "shard_map", "pallas_call",
}
IMPURE_CALLS = {
    ("time", "time"), ("time", "perf_counter"), ("time", "monotonic"),
    ("time", "time_ns"), ("time", "perf_counter_ns"),
    ("os", "urandom"),
}
SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*ok\s+(?P<rules>J\d{3}(?:\s*,\s*J\d{3})*)"
    r"(?P<why>.*)$")
# whole-module opt-out for host-eager driver files, e.g.
#   # lint: module-ok J002 — training loop syncs metrics to host each step
MODULE_SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*module-ok\s+(?P<rules>J\d{3}(?:\s*,\s*J\d{3})*)"
    r"(?P<why>.*)$")


class Finding:
    def __init__(self, path: str, line: int, col: int, rule: str,
                 msg: str):
        self.path, self.line, self.col = path, line, col
        self.rule, self.msg = rule, msg

    @property
    def key(self) -> str:
        return f"{self.path}:{self.rule}:{self.msg}"

    def __str__(self):
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.msg}"

    def to_dict(self):
        return {"path": self.path, "line": self.line, "col": self.col,
                "rule": self.rule, "msg": self.msg}


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------


def parse_suppressions(source: str, path: str
                       ) -> Tuple[Dict[int, Set[str]], Set[str],
                                  List[Finding]]:
    """Per-line + whole-module suppressed rule sets, J000 for bare ones."""
    sup: Dict[int, Set[str]] = {}
    mod: Set[str] = set()
    bad: List[Finding] = []
    for i, text in enumerate(source.splitlines(), start=1):
        m = MODULE_SUPPRESS_RE.search(text)
        if m is None:
            m = SUPPRESS_RE.search(text)
            target = None
        else:
            target = mod
        if m:
            rules = {r.strip() for r in m.group("rules").split(",")}
            why = m.group("why").strip(" -—:\t")
            if not why:
                bad.append(Finding(path, i, 0, "J000",
                                   f"suppression of {sorted(rules)} "
                                   f"carries no justification"))
            if target is None:
                sup[i] = rules
            else:
                target.update(rules)
        if "# noqa" in text:
            sup.setdefault(i, set()).add("J006")
    return sup, mod, bad


# ---------------------------------------------------------------------------
# expression helpers
# ---------------------------------------------------------------------------


def attr_chain(node: ast.AST) -> List[str]:
    """``jax.lax.scan`` -> ["jax", "lax", "scan"]; [] if not a chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def is_jax_call(node: ast.AST) -> bool:
    """A Call whose root is jnp/jax/lax and that returns a device value."""
    if not isinstance(node, ast.Call):
        return False
    chain = attr_chain(node.func)
    if not chain or chain[0] not in JAX_ROOTS:
        return False
    return not (set(chain[1:]) & HOST_SIDE_ATTRS)


# attributes of a device array that are HOST static metadata, not data
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "nbytes"}


class _TaintScan(ast.NodeVisitor):
    """Does this expression reference a jax value (directly or via a
    tainted local)?"""

    def __init__(self, tainted: Set[str]):
        self.tainted = tainted
        self.hit = False

    def visit_Attribute(self, node: ast.Attribute):
        if node.attr in STATIC_ATTRS:
            return          # x.shape / x.ndim are trace-time constants
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        if is_jax_call(node):
            self.hit = True
        chain = attr_chain(node.func)
        # int(x)/float(x)/np.asarray(x) concretize: the RESULT is host;
        # isinstance/len read static structure, never the device value
        if chain and chain[-1] in ("int", "float", "bool", "item",
                                   "asarray", "array", "isinstance",
                                   "len"):
            return
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name):
        if node.id in self.tainted:
            self.hit = True


def is_tainted(expr: ast.AST, tainted: Set[str]) -> bool:
    s = _TaintScan(tainted)
    s.visit(expr)
    return s.hit


# ---------------------------------------------------------------------------
# per-function checks (J001/J002/J004)
# ---------------------------------------------------------------------------


def _assigned_names(tgt: ast.AST) -> List[str]:
    if isinstance(tgt, ast.Name):
        return [tgt.id]
    if isinstance(tgt, (ast.Tuple, ast.List)):
        out = []
        for e in tgt.elts:
            out.extend(_assigned_names(e))
        return out
    return []


class FunctionChecker(ast.NodeVisitor):
    def __init__(self, path: str, findings: List[Finding],
                 traced: bool):
        self.path = path
        self.findings = findings
        self.traced = traced
        self.tainted: Set[str] = set()
        self.donating: Dict[str, Tuple[int, ...]] = {}
        self.donated_names: Dict[str, int] = {}   # name -> call lineno

    def add(self, node, rule, msg):
        self.findings.append(Finding(self.path, node.lineno,
                                     node.col_offset, rule, msg))

    # ---- taint propagation through simple assignments (the RHS is
    # checked FIRST, against the pre-assignment taint set)
    def visit_Assign(self, node: ast.Assign):
        self.generic_visit(node)
        names = [n for t in node.targets for n in _assigned_names(t)]
        self._track_assign(names, node.value)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        self.generic_visit(node)
        if node.value is not None:
            self._track_assign(_assigned_names(node.target), node.value)

    def visit_AugAssign(self, node: ast.AugAssign):
        self.generic_visit(node)
        # x += rhs reads x: existing taint survives an untainted RHS
        self._track_assign(_assigned_names(node.target), node.value,
                           keep=True)

    def _track_assign(self, names: List[str], value: ast.AST,
                      keep: bool = False):
        jit_donate = self._donating_jit(value)
        if jit_donate is not None and len(names) == 1:
            self.donating[names[0]] = jit_donate
            return
        if is_tainted(value, self.tainted):
            self.tainted.update(names)
        else:
            for n in names:
                self.tainted.discard(n)
                self.donating.pop(n, None)

    @staticmethod
    def _donating_jit(value: ast.AST) -> Optional[Tuple[int, ...]]:
        """``jax.jit(..., donate_argnums=(1, 2))`` -> (1, 2)."""
        if not isinstance(value, ast.Call):
            return None
        chain = attr_chain(value.func)
        if not chain or chain[-1] != "jit" or chain[0] not in JAX_ROOTS:
            return None
        for kw in value.keywords:
            if kw.arg == "donate_argnums":
                try:
                    v = ast.literal_eval(kw.value)
                except (ValueError, SyntaxError):
                    return ()
                return tuple(v) if isinstance(v, (tuple, list)) else (v,)
        return None

    # ---- J001: branches on tainted conditions
    def visit_If(self, node: ast.If):
        self._check_branch(node, node.test, "if")
        self.generic_visit(node)

    def visit_While(self, node: ast.While):
        self._check_branch(node, node.test, "while")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp):
        self._check_branch(node, node.test, "conditional expression")
        self.generic_visit(node)

    def _check_branch(self, node, test, what):
        if is_tainted(test, self.tainted):
            self.add(node, "J001",
                     f"{what} condition derives from a jax value "
                     f"(tracer under jit); use jnp.where/lax.cond")

    # ---- J002: concretization of tainted values
    def visit_Call(self, node: ast.Call):
        chain = attr_chain(node.func)
        if (chain and chain[-1] == "item" and len(chain) >= 2
                and chain[0] in self.tainted):
            self.add(node, "J002",
                     f"`.item()` on jax-derived {chain[0]!r} "
                     f"forces a sync / breaks under jit")
        if (isinstance(node.func, ast.Name)
                and node.func.id in ("float", "int", "bool")
                and node.args
                and is_tainted(node.args[0], self.tainted)):
            self.add(node, "J002",
                     f"`{node.func.id}()` concretizes a jax-derived "
                     f"value; keep it on-device or mark host-eager")
        # J003 inside traced functions
        if self.traced and chain:
            tup = (chain[0], chain[-1])
            if (tup in IMPURE_CALLS
                    or (chain[0] in ("np", "numpy", "random")
                        and "random" in chain)):
                self.add(node, "J003",
                         f"impure call {'.'.join(chain)} in traced "
                         f"code freezes at trace time; pass the value "
                         f"in as an argument")
        # J004: record donated argument names at call sites
        if (isinstance(node.func, ast.Name)
                and node.func.id in self.donating):
            for pos in self.donating[node.func.id]:
                if pos < len(node.args) and isinstance(
                        node.args[pos], ast.Name):
                    self.donated_names[node.args[pos].id] = node.lineno
        self.generic_visit(node)

    # ---- J004: reads after a donated call
    def visit_Name(self, node: ast.Name):
        if (isinstance(node.ctx, ast.Load)
                and node.id in self.donated_names
                and node.lineno > self.donated_names[node.id]):
            self.add(node, "J004",
                     f"{node.id!r} was passed at a donated position "
                     f"(donate_argnums) and read again afterwards")
            del self.donated_names[node.id]

    # nested defs: fresh scope (tainting does not leak across scopes)
    def visit_FunctionDef(self, node):
        pass

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef


# ---------------------------------------------------------------------------
# module-level orchestration
# ---------------------------------------------------------------------------


def _traced_function_names(tree: ast.Module) -> Set[str]:
    """Names of functions demonstrably traced in this module: decorated
    with / passed (positionally) to jit/vmap/scan-family transforms."""
    traced: Set[str] = set()

    class V(ast.NodeVisitor):
        def visit_Call(self, node: ast.Call):
            chain = attr_chain(node.func)
            if chain and chain[-1] in TRACE_ENTRY_ATTRS:
                for a in node.args:
                    if isinstance(a, ast.Name):
                        traced.add(a.id)
                    inner = attr_chain(a)
                    if inner and len(inner) == 1:
                        traced.add(inner[0])
            self.generic_visit(node)

        def visit_FunctionDef(self, node):
            for dec in node.decorator_list:
                chain = attr_chain(dec if not isinstance(dec, ast.Call)
                                   else dec.func)
                if chain and (chain[-1] in TRACE_ENTRY_ATTRS
                              or (len(chain) >= 2
                                  and chain[-2] in ("partial",)
                                  and any(attr_chain(a)[-1:] ==
                                          [t] for t in TRACE_ENTRY_ATTRS
                                          for a in getattr(
                                              dec, "args", [])))):
                    traced.add(node.name)
            self.generic_visit(node)

    V().visit(tree)
    return traced


def _check_cache_keys(tree: ast.Module, path: str,
                      findings: List[Finding]) -> None:
    """J005: unstable components in ``cached(key, ...)`` keys."""
    simple_assigns: Dict[str, ast.AST] = {}

    class Collect(ast.NodeVisitor):
        def visit_Assign(self, node: ast.Assign):
            if (len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                simple_assigns[node.targets[0].id] = node.value
            self.generic_visit(node)

    Collect().visit(tree)

    def unstable(expr: ast.AST) -> Optional[str]:
        for sub in ast.walk(expr):
            if isinstance(sub, (ast.ListComp, ast.SetComp, ast.DictComp,
                                ast.List, ast.Set, ast.Dict)):
                return type(sub).__name__
            if isinstance(sub, ast.Call):
                chain = attr_chain(sub.func)
                if chain and chain[-1] in ("list", "set", "dict"):
                    return f"{chain[-1]}()"
                if chain and chain[-1] in ("keys", "values"):
                    return f".{chain[-1]}() (dict order)"
        return None

    class V(ast.NodeVisitor):
        def visit_Call(self, node: ast.Call):
            chain = attr_chain(node.func)
            if chain and chain[-1] == "cached" and node.args:
                key = node.args[0]
                if isinstance(key, ast.Name):
                    key = simple_assigns.get(key.id, key)
                why = unstable(key)
                if why:
                    findings.append(Finding(
                        path, node.lineno, node.col_offset, "J005",
                        f"cache key contains {why}: unhashable or "
                        f"iteration-order dependent"))
            self.generic_visit(node)

    V().visit(tree)


def _check_unused_imports(tree: ast.Module, path: str,
                          findings: List[Finding]) -> None:
    imports: Dict[str, ast.stmt] = {}
    for node in tree.body:
        if isinstance(node, ast.Import):
            for al in node.names:
                name = (al.asname or al.name).split(".")[0]
                imports[name] = node
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for al in node.names:
                if al.name == "*":
                    continue
                imports[al.asname or al.name] = node

    used: Set[str] = set()

    class V(ast.NodeVisitor):
        def visit_Name(self, node: ast.Name):
            used.add(node.id)

        def visit_Attribute(self, node: ast.Attribute):
            chain = attr_chain(node)
            if chain:
                used.add(chain[0])
            self.generic_visit(node)

        def visit_Constant(self, node: ast.Constant):
            # string annotations: "timestore.OnlineStore"
            if isinstance(node.value, str) and re.fullmatch(
                    r"[A-Za-z_][\w.\[\], ]*", node.value):
                used.add(node.value.split(".")[0].split("[")[0].strip())

    V().visit(tree)
    for lst in ast.walk(tree):
        if (isinstance(lst, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "__all__"
                        for t in lst.targets)):
            try:
                used.update(ast.literal_eval(lst.value))
            except (ValueError, SyntaxError):
                pass
    for name, node in imports.items():
        if name not in used:
            findings.append(Finding(path, node.lineno, node.col_offset,
                                    "J006",
                                    f"import {name!r} is never used"))


def lint_source(source: str, path: str) -> List[Finding]:
    """Lint one module's source; returns unsuppressed findings."""
    findings: List[Finding] = []
    sup, mod_sup, bad = parse_suppressions(source, path)
    findings.extend(bad)
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        findings.append(Finding(path, e.lineno or 0, 0, "J000",
                                f"syntax error: {e.msg}"))
        return findings

    traced = _traced_function_names(tree)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            chk = FunctionChecker(path, findings,
                                  traced=node.name in traced)
            for stmt in node.body:
                chk.visit(stmt)
    _check_cache_keys(tree, path, findings)
    _check_unused_imports(tree, path, findings)

    out = []
    for f in findings:
        if f.rule != "J000" and (f.rule in mod_sup
                                 or f.rule in sup.get(f.line, set())):
            continue
        out.append(f)
    return out


def lint_paths(paths: List[pathlib.Path]) -> List[Finding]:
    files: List[pathlib.Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    findings: List[Finding] = []
    for f in files:
        rel = str(f)
        findings.extend(lint_source(f.read_text(), rel))
    return findings


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="lint_repro", description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=["src"])
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--baseline",
                    default=str(pathlib.Path(__file__).parent
                                / "lint_baseline.json"))
    args = ap.parse_args(argv)

    baseline: Set[str] = set()
    bp = pathlib.Path(args.baseline)
    if bp.exists():
        baseline = {e["key"] for e in
                    json.loads(bp.read_text()).get("findings", [])}

    findings = lint_paths([pathlib.Path(p) for p in args.paths])
    fresh = [f for f in findings if f.key not in baseline]
    if args.json:
        print(json.dumps([f.to_dict() for f in fresh], indent=1))
    else:
        for f in fresh:
            print(f)
        print(f"lint_repro: {len(fresh)} finding(s) "
              f"({len(findings) - len(fresh)} baselined) over "
              f"{len(args.paths)} path(s)")
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
