"""CI gate: serving-loop record/replay determinism + offline parity.

Three layers of ISSUE 7's hard gate, in one run over a mixed
request/ingest trace with mid-trace retention eviction and compaction:

  * **replay-vs-replay** — the recorded trace, round-tripped through
    JSON, is replayed twice through fresh engines; every served feature
    array AND every leaf of the final store state must be bitwise
    identical (``np.array_equal``).
  * **recorded-vs-replayed** — the replayed outputs must also match the
    original recording run byte for byte (replay reproduces the run,
    not merely *a* deterministic run).
  * **serving-vs-offline** — the replayed outputs, reordered to offline
    row order, must pass ``verify_consistency(bitwise=True)`` against
    ``cs.offline(tables)``: the loop's batching/admission/snapshot
    machinery adds NOTHING to the bytes the fold engine defines.

Prices are floored to integer-valued f32 so the float sums stay exact
through the eviction anchor move (same trick as check_recovery.py);
the engine runs ``retention="auto"`` with a small ``compact_every`` so
eviction genuinely fires inside the trace — the run aborts if it
did not.

    PYTHONPATH=src python tools/check_replay.py [n_actions]
"""

from __future__ import annotations

import sys
import tempfile

try:
    from tools._common import RAW_SQL, int_prices, tail_int_argv
except ImportError:                      # invoked as `python tools/x.py`
    from _common import RAW_SQL, int_prices, tail_int_argv

import numpy as np  # noqa: E402

from repro.core import verify_consistency  # noqa: E402
from repro.data.synthetic import make_action_tables  # noqa: E402
from repro.serve.engine import FeatureEngine  # noqa: E402
from repro.serve.trace import (load_trace, outputs_in_base_order,  # noqa
                               record_consistency_trace, replay,
                               save_trace, store_state_arrays)

REPLAY_KW = dict(batch_size=1, max_wait_ms=0.0, slo_ms=1e6)


def _arrays_equal(a, b, what: str) -> bool:
    for k in a:
        if not np.array_equal(np.asarray(a[k]), np.asarray(b[k])):
            print(f"replay: FAIL {what} feature {k!r} differs")
            return False
    return True


def main(n_actions: int = 90) -> int:
    tables = int_prices(make_action_tables(
        n_actions=n_actions, n_orders=0, n_users=4, horizon_ms=600_000,
        seed=7, with_profile=False))

    def factory():
        return FeatureEngine(RAW_SQL, tables, capacity=256,
                             retention="auto", compact_every=16)

    eng = factory()
    loop0, events, rids = record_consistency_trace(eng, tables)
    evicted = n_actions - eng.store.n_rows("actions")
    if evicted <= 0:
        print("replay: FAIL trace produced no eviction — gate is vacuous")
        return 1

    with tempfile.NamedTemporaryFile(suffix=".json") as f:
        save_trace(events, f.name)
        events2 = load_trace(f.name)
    lp1 = replay(events2, factory, **REPLAY_KW)
    lp2 = replay(events2, factory, **REPLAY_KW)

    cs = eng.cs
    out0 = outputs_in_base_order(loop0, rids, tables, cs)
    out1 = outputs_in_base_order(lp1, rids, tables, cs)
    out2 = outputs_in_base_order(lp2, rids, tables, cs)

    ok = _arrays_equal(out1, out2, "replay-vs-replay")
    st1, st2 = store_state_arrays(lp1.engine), store_state_arrays(lp2.engine)
    for (pa, xa), (pb, xb) in zip(st1, st2):
        if pa != pb or not np.array_equal(xa, xb):
            print(f"replay: FAIL final store leaf {pa} differs")
            ok = False
            break
    if ok:
        print(f"replay    : {len(events2)} events, {n_actions} requests, "
              f"{evicted} rows evicted mid-trace -> replay x2 "
              f"BITWISE-EQUAL ({len(st1)} store leaves)")

    ok2 = _arrays_equal(out0, out1, "recorded-vs-replayed")
    if ok2:
        print(f"recorded  : replay reproduces the recording run byte for "
              f"byte ({n_actions}x{len(out0)} features)")
    ok &= ok2

    rep = verify_consistency(cs, tables, bitwise=True,
                             online_outputs=out1)
    print(f"offline   : {rep}")
    ok &= rep.passed and rep.bitwise_equal
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(tail_int_argv(None, 90)[0]))
