"""Shared plumbing for the tools/ CI gates.

Every gate needs the same four things: ``src/`` importable regardless
of the invoking directory, the two canonical gate scripts (short raw
window + long pre-agg window), the integer-valued-price trick that
makes float combines bitwise, and tail-int argv parsing.  Keeping them
here means a gate script is only its actual assertions.
"""

from __future__ import annotations

import pathlib
import sys
from typing import List, Optional, Tuple

_SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")


def ensure_src_on_path() -> None:
    """Make ``import repro`` work from any invoking directory."""
    if _SRC not in sys.path:
        sys.path.insert(0, _SRC)


ensure_src_on_path()

# The two canonical gate scripts.  RAW: short window, no pre-agg —
# exercises the gather + unit-fold serving path.  PREAGG: 3000s window
# with 100s buckets — exercises the §5.1 pre-agg planes.
RAW_SQL = """
SELECT sum(price) OVER w AS s, count(price) OVER w AS c,
       max(price) OVER w AS mx, min(price) OVER w AS mn
FROM actions
WINDOW w AS (PARTITION BY userid ORDER BY ts
             ROWS_RANGE BETWEEN 10s PRECEDING AND CURRENT ROW)
"""

PREAGG_SQL = """
SELECT sum(price) OVER w AS s, count(price) OVER w AS c,
       max(price) OVER w AS mx
FROM actions
WINDOW w AS (PARTITION BY userid ORDER BY ts
             ROWS_RANGE BETWEEN 3000s PRECEDING AND CURRENT ROW)
OPTIONS (long_windows = "w:100s")
"""


def int_prices(tables):
    """Floor prices to integer-valued float32 in place.

    Every f32 combine over integer-valued operands (within 2**24) is
    exact, so even the re-bracketed pre-agg path is bitwise — the
    analyzer's C-PREAGG-FLOAT rule stays conservative about this, the
    gates exploit it deliberately.
    """
    import numpy as np

    for t in tables.values():
        if "price" in t.columns:
            t.columns["price"] = np.floor(t.columns["price"]).astype(
                np.float32)
    return tables


def tail_int_argv(argv: Optional[List[str]], default: int,
                  *flags: str) -> Tuple[int, dict]:
    """Parse ``[--flag ...] [n]`` tails shared by every gate CLI.

    Returns ``(n, {flag_name: bool})`` where flag names are stripped of
    the leading dashes.
    """
    argv = list(sys.argv[1:] if argv is None else argv)
    seen = {f.lstrip("-"): False for f in flags}
    for f in flags:
        if f in argv:
            seen[f.lstrip("-")] = True
            argv = [a for a in argv if a != f]
    return (int(argv[0]) if argv else default), seen
