"""CI gate: kill-shard -> promote -> bitwise parity vs unsharded serving.

Two layers of the same contract (ISSUE 6's hard gate):

  * ``verify_consistency(..., replication=1, kill_shard_at=k)`` — the
    offline reference never sees the fault while the online replay
    kills the owner shard of request k mid-traffic and fails over to a
    follower; the report must still be bitwise (raw serving always,
    pre-agg on integer-valued prices where every combine bracketing is
    f32-exact).
  * engine-level ``kill_shard``/``heal`` on ``FeatureEngine`` with
    traffic continuing while the shard is dead, gated ``array_equal``
    per feature against an unsharded engine fed identical rows.

    PYTHONPATH=src python tools/check_recovery.py [n_shards]
"""

from __future__ import annotations

import sys

try:
    from tools._common import PREAGG_SQL, RAW_SQL, int_prices, tail_int_argv
except ImportError:                      # invoked as `python tools/x.py`
    from _common import PREAGG_SQL, RAW_SQL, int_prices, tail_int_argv

import numpy as np  # noqa: E402

from repro.core import compile_script, parse, verify_consistency  # noqa
from repro.data.synthetic import make_action_tables  # noqa: E402
from repro.serve.engine import FeatureEngine  # noqa: E402


def _engine_gate(n_shards: int) -> bool:
    tables = make_action_tables(n_actions=220, n_orders=0, n_users=8,
                                horizon_ms=12_000_000, seed=21,
                                with_profile=False)
    ref = FeatureEngine(RAW_SQL, tables, capacity=1024)
    rep = FeatureEngine(RAW_SQL, tables, capacity=1024,
                        n_shards=n_shards, replication=1, ship_every=32)
    a = tables["actions"]
    rows = [a.row(i) for i in range(180)]
    ref.ingest_many("actions", rows[:120])
    rep.ingest_many("actions", rows[:120])
    rep.kill_shard(1)
    ref.ingest_many("actions", rows[120:])   # traffic while dead
    rep.ingest_many("actions", rows[120:])
    recs = rep.heal()
    probe = [a.row(190 + i) for i in range(12)]
    r1 = ref.request_batch([dict(r) for r in probe])
    r2 = rep.request_batch([dict(r) for r in probe])
    for i in range(len(probe)):
        for k in r1[i]:
            if not np.array_equal(np.asarray(r1[i][k]),
                                  np.asarray(r2[i][k])):
                print(f"engine    (S={n_shards}): FAIL req {i} "
                      f"feature {k}")
                return False
    rec = recs[0]
    print(f"engine    (S={n_shards}): kill shard 1 -> promote replica "
          f"{rec.replica}, replay {rec.replayed_entries} entries, "
          f"recover {rec.recovery_s * 1e3:.1f}ms -> BITWISE-EQUAL "
          f"({len(probe)}x{len(r1[0])} features)")
    return True


def main(n_shards: int = 4) -> int:
    ok = True

    tables = make_action_tables(n_actions=150, n_orders=0, n_users=6,
                                seed=11, with_profile=False)
    cs = compile_script(parse(RAW_SQL), tables=tables)
    rep = verify_consistency(cs, tables, n_shards=n_shards, bitwise=True,
                             replication=1, kill_shard_at=5, ship_every=7)
    print(f"raw+kill  (S={n_shards}): {rep}")
    ok &= rep.passed

    tables2 = int_prices(make_action_tables(
        n_actions=120, n_orders=0, n_users=4, horizon_ms=12_000_000,
        seed=13, with_profile=False))
    cs2 = compile_script(parse(PREAGG_SQL), tables=tables2)
    rep2 = verify_consistency(cs2, tables2, use_preagg=True,
                              n_shards=n_shards, bitwise=True,
                              replication=1, kill_shard_at=9,
                              ship_every=5)
    print(f"preagg+kill(S={n_shards}): {rep2}")
    ok &= rep2.passed

    ok &= _engine_gate(n_shards)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(tail_int_argv(None, 4)[0]))
