"""CI gate: kill-shard -> promote -> bitwise parity vs unsharded serving.

Two layers of the same contract (ISSUE 6's hard gate):

  * ``verify_consistency(..., replication=1, kill_shard_at=k)`` — the
    offline reference never sees the fault while the online replay
    kills the owner shard of request k mid-traffic and fails over to a
    follower; the report must still be bitwise (raw serving always,
    pre-agg on integer-valued prices where every combine bracketing is
    f32-exact).
  * engine-level ``kill_shard``/``heal`` on ``FeatureEngine`` with
    traffic continuing while the shard is dead, gated ``array_equal``
    per feature against an unsharded engine fed identical rows.

    PYTHONPATH=src python tools/check_recovery.py [n_shards]
"""

from __future__ import annotations

import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import compile_script, parse, verify_consistency  # noqa
from repro.data.synthetic import make_action_tables  # noqa: E402
from repro.serve.engine import FeatureEngine  # noqa: E402

RAW_SQL = """
SELECT sum(price) OVER w AS s, count(price) OVER w AS c,
       max(price) OVER w AS mx, min(price) OVER w AS mn
FROM actions
WINDOW w AS (PARTITION BY userid ORDER BY ts
             ROWS_RANGE BETWEEN 10s PRECEDING AND CURRENT ROW)
"""

PREAGG_SQL = """
SELECT sum(price) OVER w AS s, count(price) OVER w AS c,
       max(price) OVER w AS mx
FROM actions
WINDOW w AS (PARTITION BY userid ORDER BY ts
             ROWS_RANGE BETWEEN 3000s PRECEDING AND CURRENT ROW)
OPTIONS (long_windows = "w:100s")
"""


def _int_prices(tables):
    """Integer-valued f32 prices: re-bracketed combines stay bitwise."""
    for t in tables.values():
        if "price" in t.columns:
            t.columns["price"] = np.floor(t.columns["price"]).astype(
                np.float32)
    return tables


def _engine_gate(n_shards: int) -> bool:
    tables = make_action_tables(n_actions=220, n_orders=0, n_users=8,
                                horizon_ms=12_000_000, seed=21,
                                with_profile=False)
    ref = FeatureEngine(RAW_SQL, tables, capacity=1024)
    rep = FeatureEngine(RAW_SQL, tables, capacity=1024,
                        n_shards=n_shards, replication=1, ship_every=32)
    a = tables["actions"]
    rows = [a.row(i) for i in range(180)]
    ref.ingest_many("actions", rows[:120])
    rep.ingest_many("actions", rows[:120])
    rep.kill_shard(1)
    ref.ingest_many("actions", rows[120:])   # traffic while dead
    rep.ingest_many("actions", rows[120:])
    recs = rep.heal()
    probe = [a.row(190 + i) for i in range(12)]
    r1 = ref.request_batch([dict(r) for r in probe])
    r2 = rep.request_batch([dict(r) for r in probe])
    for i in range(len(probe)):
        for k in r1[i]:
            if not np.array_equal(np.asarray(r1[i][k]),
                                  np.asarray(r2[i][k])):
                print(f"engine    (S={n_shards}): FAIL req {i} "
                      f"feature {k}")
                return False
    rec = recs[0]
    print(f"engine    (S={n_shards}): kill shard 1 -> promote replica "
          f"{rec.replica}, replay {rec.replayed_entries} entries, "
          f"recover {rec.recovery_s * 1e3:.1f}ms -> BITWISE-EQUAL "
          f"({len(probe)}x{len(r1[0])} features)")
    return True


def main(n_shards: int = 4) -> int:
    ok = True

    tables = make_action_tables(n_actions=150, n_orders=0, n_users=6,
                                seed=11, with_profile=False)
    cs = compile_script(parse(RAW_SQL), tables=tables)
    rep = verify_consistency(cs, tables, n_shards=n_shards, bitwise=True,
                             replication=1, kill_shard_at=5, ship_every=7)
    print(f"raw+kill  (S={n_shards}): {rep}")
    ok &= rep.passed

    tables2 = _int_prices(make_action_tables(
        n_actions=120, n_orders=0, n_users=4, horizon_ms=12_000_000,
        seed=13, with_profile=False))
    cs2 = compile_script(parse(PREAGG_SQL), tables=tables2)
    rep2 = verify_consistency(cs2, tables2, use_preagg=True,
                              n_shards=n_shards, bitwise=True,
                              replication=1, kill_shard_at=9,
                              ship_every=5)
    print(f"preagg+kill(S={n_shards}): {rep2}")
    ok &= rep2.passed

    ok &= _engine_gate(n_shards)
    return 0 if ok else 1


if __name__ == "__main__":
    argv = sys.argv[1:]
    sys.exit(main(int(argv[0]) if argv else 4))
