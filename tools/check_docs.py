"""Docs sanity gate: every ``repro.*`` dotted path named in README.md or
docs/*.md must resolve against the actual package.

A path resolves when its longest importable module prefix imports and
any remaining components resolve as attributes (classes, functions,
methods) — so ``repro.core.compiler.CompiledScript.online_sharded_batch``
is checked end-to-end, and a doc that drifts from a rename fails CI.

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import importlib
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
PATTERN = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z0-9_]*)+")


def resolve(path: str) -> str | None:
    """Return an error string, or None if the dotted path resolves."""
    parts = path.split(".")
    obj = None
    mod_err = None
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
            rest = parts[i:]
            break
        except ImportError as e:
            mod_err = str(e)
    else:
        return f"no importable module prefix ({mod_err})"
    for attr in rest:
        try:
            obj = getattr(obj, attr)
        except AttributeError:
            return f"{type(obj).__name__} has no attribute {attr!r}"
    return None


def main() -> int:
    failures = []
    n_paths = 0
    for doc in DOC_FILES:
        if not doc.exists():
            failures.append((str(doc), "(file missing)"))
            continue
        seen = set()
        for m in PATTERN.finditer(doc.read_text()):
            path = m.group(0).rstrip(".")
            if path in seen:
                continue
            seen.add(path)
            n_paths += 1
            err = resolve(path)
            if err is not None:
                failures.append((f"{doc.relative_to(ROOT)}: {path}", err))
    for where, err in failures:
        print(f"FAIL {where}: {err}")
    print(f"checked {n_paths} repro.* paths across "
          f"{len(DOC_FILES)} docs: "
          f"{'OK' if not failures else f'{len(failures)} broken'}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
