"""Static plan certifier CLI: ``python -m tools.analyze_plan <script>``.

Compiles a feature script, runs the static analyzer
(``repro.core.analysis.certify``), and prints the deployment
certificate — per-column consistency class, retrace bound, shard
eligibility reason tree, and the steady-state memory bound — without
executing the plan on a single request.

``<script>`` is either a ``.sql`` file or a ``.py`` module with a
module-level ``SQL`` constant (the examples/ convention).  Synthetic
tables sized to the script's features supply the data statistics that
discharge the data-dependent rules; ``--no-tables`` certifies from the
plan alone (strictly more conservative).

``--cross-check`` additionally replays the script through
``verify_consistency(bitwise=True)`` and enforces the certifier's
contract: every column the certificate calls BITWISE must be observed
bitwise-equal dynamically (the converse is allowed — static tolerance
is a non-promise, not a prediction of inequality).

    PYTHONPATH=src python -m tools.analyze_plan examples/quickstart.py \\
        --cross-check --json certs/CERT_quickstart.json
"""

from __future__ import annotations

import argparse
import ast
import pathlib
import sys

try:
    from tools._common import int_prices  # noqa: F401  (re-export for tests)
except ImportError:                      # invoked as `python tools/x.py`
    from _common import int_prices  # noqa: F401

from repro.core import compile_script, parse, verify_consistency
from repro.core.analysis import certify
from repro.data.synthetic import make_action_tables


def load_sql(path: pathlib.Path) -> str:
    """Extract the script: raw ``.sql``, or the ``SQL`` constant of a
    ``.py`` module (parsed statically — the module is never imported)."""
    text = path.read_text()
    if path.suffix != ".py":
        return text
    for node in ast.parse(text).body:
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "SQL"
                        for t in node.targets)
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, str)):
            return node.value.value
    raise SystemExit(f"analyze_plan: no module-level SQL constant in {path}")


def synthetic_tables(sql: str, n_actions: int = 150, seed: int = 11):
    """Tables shaped to the script: long horizon iff it pre-aggregates,
    orders/profile only when the script reads them."""
    horizon = 12_000_000 if "long_windows" in sql else 60_000
    return make_action_tables(
        n_actions=n_actions,
        n_orders=n_actions // 2 if "orders" in sql else 0,
        n_users=6, horizon_ms=horizon, seed=seed,
        with_profile="profile" in sql)


def cross_check(cert, cs, tables) -> int:
    """Enforce conservative agreement; returns the number of failures.

    Under ``bitwise=True`` the report's ``mismatched`` list is exactly
    the non-bitwise columns, so the check is column-exact: every column
    the certificate marks bitwise must be absent from it.  Static
    tolerance is a non-promise — a dynamically-bitwise tolerance column
    is fine (e.g. integer-valued floats).
    """
    failures = 0
    for mode, use_preagg in (("raw", False), ("preagg", True)):
        if use_preagg and not any(w.preagg for w in cs.windows):
            continue
        rep = verify_consistency(cs, tables, use_preagg=use_preagg,
                                 bitwise=True)
        not_bitwise = set(rep.mismatched)
        for col, entry in cert.consistency["columns"].items():
            if entry[mode] == "bitwise" and col in not_bitwise:
                print(f"cross-check: FAIL {mode} column {col!r}: "
                      f"certified bitwise, observed tolerance-only")
                failures += 1
        n_static = sum(e[mode] == "bitwise"
                       for e in cert.consistency["columns"].values())
        print(f"cross-check: {mode}: {n_static} certified-bitwise "
              f"columns, {len(not_bitwise)} dynamically non-bitwise "
              f"({sorted(not_bitwise)})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="analyze_plan", description=__doc__.splitlines()[0])
    ap.add_argument("script", help=".sql file or .py with SQL constant")
    ap.add_argument("--json", metavar="OUT",
                    help="also write the certificate JSON here")
    ap.add_argument("--cross-check", action="store_true",
                    help="replay through verify_consistency and enforce "
                         "conservative agreement")
    ap.add_argument("--no-tables", action="store_true",
                    help="certify from the plan alone (conservative)")
    ap.add_argument("--capacity", type=int, default=None,
                    help="store capacity bound for the no-tables case")
    ap.add_argument("--n-actions", type=int, default=150)
    args = ap.parse_args(argv)

    sql = load_sql(pathlib.Path(args.script))
    tables = None if args.no_tables else synthetic_tables(
        sql, n_actions=args.n_actions)
    cs = compile_script(parse(sql), tables=tables)
    cert = certify(cs, tables=tables, capacity=args.capacity)

    print(cert.summary())
    if args.json:
        out = pathlib.Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(cert.to_json() + "\n")
        print(f"certificate -> {out}")

    if args.cross_check:
        if tables is None:
            raise SystemExit("analyze_plan: --cross-check needs tables "
                             "(drop --no-tables)")
        failures = cross_check(cert, cs, tables)
        if failures:
            return 1
        print("cross-check: certificate is conservative-consistent with "
              "the dynamic gate")
    return 0


if __name__ == "__main__":
    sys.exit(main())
