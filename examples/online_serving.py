"""End-to-end online ML serving — the paper's production shape.

Event streams feed the online store (with async pre-aggregation for the
long window); each incoming request computes fresh features in
millisecond latency and scores them with a served LM (batched decode).
This is the end-to-end driver the paper's kind dictates (serving, not
training): feature freshness + model scoring in one loop.

Run:  PYTHONPATH=src python examples/online_serving.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced
from repro.data.synthetic import make_action_tables
from repro.models import init_params
from repro.serve.batcher import RequestBatcher
from repro.serve.engine import FeatureEngine, ServingEngine

SQL = """
SELECT
  sum(price) OVER w_recent AS spend_recent,
  count(price) OVER w_recent AS n_recent,
  avg(price) OVER w_long AS avg_long,
  max(price) OVER w_long AS max_long
FROM actions
WINDOW w_recent AS (UNION orders PARTITION BY userid ORDER BY ts
                    ROWS_RANGE BETWEEN 60s PRECEDING AND CURRENT ROW),
      w_long AS (PARTITION BY userid ORDER BY ts
                 ROWS_RANGE BETWEEN 2000s PRECEDING AND CURRENT ROW)
OPTIONS (long_windows = "w_long:100s")
"""


def main():
    print("== setup: stores + pre-aggregation + model")
    tables = make_action_tables(n_actions=1200, n_orders=600, n_users=16,
                                horizon_ms=3_000_000, with_profile=False)
    feats = FeatureEngine(SQL, tables, capacity=4096, use_preagg=True,
                          ttl_ms=0)
    cfg = reduced("qwen3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    model = ServingEngine(cfg, params, max_len=64, dtype=jnp.float32)
    batcher = RequestBatcher(batch_size=4, max_wait_ms=2.0)

    a, o = tables["actions"], tables["orders"]
    print("== stream: interleave ingest + requests")
    scored = 0
    for i in range(300):
        feats.ingest("actions", a.row(i))
        if i % 2 == 0:
            feats.ingest("orders", o.row(i))
        if i % 3 == 0:
            f = feats.request(dict(a.row(i)))
            tok = int(f["n_recent"]) % cfg.vocab_size
            batcher.submit(tok)
        if batcher.ready():
            _, toks, n_real = batcher.next_batch(pad_with=0)
            prompt = jnp.asarray(np.asarray(toks, np.int32)[:, None])
            model.generate_greedy({"tokens": prompt}, n_tokens=4)
            scored += n_real
    pct = feats.latency_percentiles()
    print(f"== done: {feats.n_requests} feature requests, "
          f"{scored} model scorings")
    print(f"   feature latency TP50={pct['TP50']:.2f}ms "
          f"TP99={pct['TP99']:.2f}ms (paper targets: 4-20ms)")
    print(f"   decode batches={batcher.batches_emitted}, "
          f"padding={batcher.padded_slots}")


if __name__ == "__main__":
    main()
