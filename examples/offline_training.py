"""Offline mode end-to-end: feature computation -> LM training.

The offline engine computes features over history (the same compiled
script the online engine serves), and the training substrate runs a
real multi-step LM training loop with checkpointing, gradient
compression, and fault-tolerance bookkeeping.

Defaults are CPU-sized; ``--steps 300 --d-model 512`` reproduces a
~100M-parameter run on accelerators.

Run:  PYTHONPATH=src python examples/offline_training.py [--steps N]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, reduced
from repro.core import compile_script, parse
from repro.data.pipeline import FeatureDataPipeline, TokenPipeline
from repro.data.synthetic import make_action_tables
from repro.distributed.compression import int8_compress
from repro.distributed.fault import CheckpointManager
from repro.models import init_params
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.steps import build_train_step

SQL = """
SELECT
  sum(price) OVER w AS f_spend,
  avg(price) OVER w AS f_avg,
  count(price) OVER w AS f_n,
  max(price) OVER w AS f_max,
  distinct_count(category) OVER w AS f_cats
FROM actions
WINDOW w AS (PARTITION BY userid ORDER BY ts
             ROWS_RANGE BETWEEN 60s PRECEDING AND CURRENT ROW)
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--compress", action="store_true")
    args = ap.parse_args()

    print("== 1. offline feature computation (training-side driver)")
    tables = make_action_tables(n_actions=2000, n_orders=0, n_users=16,
                                with_profile=False)
    cs = compile_script(parse(SQL), tables=tables)
    pipe = FeatureDataPipeline(cs, tables, batch_size=args.batch)
    mat = pipe.feature_matrix()
    print(f"   features: {mat.shape} (finite={np.isfinite(mat).all()})")

    print("== 2. LM training loop (checkpoint/restart + compression)")
    base = reduced("llama3-8b")
    cfg = dataclasses.replace(
        base, name="demo-lm", n_layers=args.layers,
        d_model=args.d_model, n_heads=max(4, args.d_model // 32),
        n_kv_heads=max(2, args.d_model // 64),
        head_dim=32, d_ff=args.d_model * 4)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    n_params = sum(np.prod(p.shape) for p in
                   jax.tree_util.tree_leaves(params))
    print(f"   model: {cfg.n_layers}L d={cfg.d_model} "
          f"({n_params / 1e6:.1f}M params)")

    state = adamw_init(params, with_compression=args.compress)
    step_fn = jax.jit(build_train_step(
        cfg, AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=args.steps,
                         weight_decay=0.0),
        n_micro=2, compress=int8_compress if args.compress else None,
        compute_dtype=jnp.float32))
    tokens = TokenPipeline(cfg.vocab_size, args.batch, args.seq)
    mgr = CheckpointManager("checkpoints/offline_demo", keep=2)

    losses = []
    t0 = time.time()
    for batch in tokens.batches(args.steps):
        state, metrics = step_fn(state, {"tokens": jnp.asarray(
            batch["tokens"])})
        losses.append(float(metrics["loss"]))
        step = int(metrics["step"])
        if step % 10 == 0:
            mgr.save(step, state)
            print(f"   step {step:4d} loss={losses[-1]:.4f} "
                  f"({(time.time() - t0) / step:.2f}s/step)")

    print(f"== 3. loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(drop {losses[0] - losses[-1]:.3f})")
    assert losses[-1] < losses[0]

    print("== 4. simulated failure: restore from checkpoint and continue")
    state2 = mgr.restore(state)
    state2, metrics = step_fn(state2, {"tokens": jnp.asarray(
        tokens.batch_at(0)["tokens"])})
    print(f"   resumed at step {int(metrics['step'])} "
          f"loss={float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
