"""Quickstart: deploy a feature script once, use it offline AND online.

This is the paper's Figure 1 scenario end-to-end:
  1. define the feature script (extended SQL with WINDOW UNION,
     topn_frequency, avg_cate_where, LAST JOIN),
  2. compile it ONCE (unified plan generator),
  3. offline mode: batch features over historical tables (training side),
  4. online mode: per-request features against the live store (serving),
  5. verify both agree (the consistency that takes the paper's users
     months to establish across Spark + Flink stacks).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import compile_script, parse, verify_consistency
from repro.data.synthetic import make_action_tables
from repro.serve.engine import FeatureEngine

SQL = """
SELECT
  distinct_count(category) OVER w_union_3s AS product_count,
  avg_cate_where(price, quantity > 1, category)
      OVER w_union_3s AS product_prices,
  sum(price) OVER w_action_100d AS spend_100d,
  topn_frequency(category, 3) OVER w_action_100d AS favourite_products,
  profile.age AS age,
  price * quantity AS order_value
FROM actions
LAST JOIN profile ORDER BY ts ON actions.userid = profile.userid
WINDOW w_union_3s AS (UNION orders PARTITION BY userid ORDER BY ts
                      ROWS_RANGE BETWEEN 3s PRECEDING AND CURRENT ROW),
      w_action_100d AS (PARTITION BY userid ORDER BY ts
                        ROWS_RANGE BETWEEN 100d PRECEDING AND CURRENT ROW)
"""


def main():
    print("== 1. historical tables (actions / orders / profile)")
    tables = make_action_tables(n_actions=400, n_orders=250, n_users=8,
                                horizon_ms=2_000_000)
    for name, t in tables.items():
        print(f"   {name}: {t.n_rows} rows")

    print("== 2. compile the feature script (one plan, two drivers)")
    cs = compile_script(parse(SQL), tables=tables)
    print(cs.describe_plan())

    print("== 3. offline mode (training features)")
    feats = cs.offline(tables)
    for name, v in feats.items():
        print(f"   {name:20s} shape={v.shape} "
              f"sample={np.round(np.atleast_1d(v[0])[:3], 2)}")

    print("== 4. online request mode (serving features)")
    eng = FeatureEngine(SQL, tables, capacity=2048)
    eng.bulk_load("actions", tables["actions"])
    eng.bulk_load("orders", tables["orders"])
    eng.bulk_load("profile", tables["profile"])
    req = dict(tables["actions"].row(399))
    out = eng.request(req)
    for name, v in out.items():
        print(f"   {name:20s} = {np.round(np.atleast_1d(v)[:3], 2)}")
    print(f"   latency: {eng.latency_percentiles()}")

    print("== 5. offline/online consistency")
    report = verify_consistency(cs, tables)
    print(f"   {report}")
    assert report.passed


if __name__ == "__main__":
    main()
